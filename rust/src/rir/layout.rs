//! The DRAM word layout of a bundle stream (paper Fig 3(d) / §IV).
//!
//! The FPGA's read controller consumes bundles as a flat sequence of 32-bit
//! words: a metadata word (element count, flags), the shared-feature word,
//! then the distinct/value pairs. The write controller produces the same
//! layout in reverse order per §IV ("It reads the metadata first, shared
//! feature next, and finally the distinct elements").
//!
//! This module is both the wire format (serialize/deserialize, used by the
//! runtime tests and the `gen-stream` CLI) and the **byte accounting** the
//! DRAM bandwidth model charges for each bundle.
//!
//! Bundles whose [`BundleFlags::CHECKSUM`] bit is set carry one extra
//! CRC32 word after the payload (ARCHITECTURE.md §3.3): the IEEE 802.3
//! checksum of the bundle's preceding words — metadata word, shared word
//! and payload — over their little-endian byte serialization.
//! [`try_deserialize`] verifies it; [`serialize_stream_checksummed`]
//! produces the protected form of an arena stream.

use anyhow::Result;

use crate::sparse::{Idx, Val};

use super::bundle::{Bundle, BundleFlags, Payload, RlTriple};
use super::error::RirError;

/// Bytes per stream word (the design streams 32-bit index + 32-bit f32).
pub const WORD_BYTES: usize = 4;

/// IEEE 802.3 CRC32 lookup table (reflected polynomial `0xEDB88320`).
static CRC32_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE 802.3 CRC32 of a word sequence, taken over the words'
/// little-endian byte serialization — the exact bytes the DRAM link
/// carries, so a software `crc32` of the raw stream buffer agrees with
/// the per-bundle words the FPGA input controller checks.
pub fn crc32_words(words: &[u32]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &w in words {
        for b in w.to_le_bytes() {
            crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xff) as usize];
        }
    }
    !crc
}

/// Number of 32-bit words a bundle occupies in DRAM.
///
/// metadata word + shared word + payload (2 words per data pair, 3 words
/// per schedule triple), plus one CRC32 word when the bundle is
/// checksummed.
pub fn bundle_words(b: &Bundle) -> usize {
    2 + match &b.payload {
        Payload::Data { distinct, .. } => 2 * distinct.len(),
        Payload::Schedule { triples } => 3 * triples.len(),
    } + usize::from(b.flags.checksum())
}

/// Bytes a bundle occupies in DRAM.
pub fn bundle_bytes(b: &Bundle) -> usize {
    bundle_words(b) * WORD_BYTES
}

/// Total bytes of a bundle stream.
pub fn stream_bytes(bundles: &[Bundle]) -> usize {
    bundles.iter().map(bundle_bytes).sum()
}

/// Serialize a bundle stream to the flat word layout.
pub fn serialize(bundles: &[Bundle]) -> Vec<u32> {
    let mut words = Vec::with_capacity(bundles.iter().map(bundle_words).sum());
    for b in bundles {
        let count = b.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        let meta = (count << 8) | b.flags.0 as u32;
        words.push(meta);
        words.push(b.shared);
        match &b.payload {
            Payload::Data { distinct, values } => {
                for (&d, &v) in distinct.iter().zip(values) {
                    words.push(d);
                    words.push(v.to_bits());
                }
            }
            Payload::Schedule { triples } => {
                for t in triples {
                    words.push(t.row);
                    words.push(t.start);
                    words.push(t.end);
                }
            }
        }
        if b.flags.checksum() {
            let crc = crc32_words(&words[start..]);
            words.push(crc);
        }
    }
    words
}

/// Number of 32-bit words a [`BundleStream`](super::encode::BundleStream)
/// occupies in DRAM (all bundles are data bundles: 2 header words + 2 per
/// element, plus one CRC32 word per checksummed bundle — the encoders
/// never set [`BundleFlags::CHECKSUM`], so for encoder-produced arenas
/// this stays exactly `2·bundles + 2·elems`).
pub fn stream_arena_words(s: &super::encode::BundleStream) -> usize {
    2 * s.n_bundles() + 2 * s.n_elems() + s.flags.iter().filter(|f| f.checksum()).count()
}

/// Bytes a [`BundleStream`](super::encode::BundleStream) occupies in DRAM.
pub fn stream_arena_bytes(s: &super::encode::BundleStream) -> usize {
    stream_arena_words(s) * WORD_BYTES
}

/// Number of 32-bit words bundles `[lo, hi)` of a stream arena occupy in
/// DRAM — one job's segment of a multi-tenant stream (see
/// [`super::encode::BundleStream::encode_csr_jobs`]). Summing every job's
/// segment reproduces [`stream_arena_words`] exactly.
pub fn segment_arena_words(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    assert!(lo <= hi && hi <= s.n_bundles(), "segment [{lo}, {hi}) out of bounds");
    2 * (hi - lo)
        + 2 * (s.off[hi] - s.off[lo])
        + s.flags[lo..hi].iter().filter(|f| f.checksum()).count()
}

/// Bytes bundles `[lo, hi)` of a stream arena occupy in DRAM.
pub fn segment_arena_bytes(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    segment_arena_words(s, lo, hi) * WORD_BYTES
}

/// Number of 32-bit words the dense-panel segment of an SpMM stream
/// occupies in DRAM (see
/// [`BundleStream::encode_csr_with_panel`](super::encode::BundleStream::encode_csr_with_panel)):
/// one chain per panel row, `ceil(k / bundle_size)` bundles per chain at
/// 2 header words each, plus 2 words per element — the same data-bundle
/// layout as the sparse stream, `k` elements per row. Zero when `k == 0`
/// (a zero-width panel contributes no bundles). Cross-checked against the
/// real encoder in the tests below.
pub fn dense_panel_words(nrows: usize, k: usize, bundle_size: usize) -> usize {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if k == 0 {
        return 0;
    }
    nrows * (2 * k.div_ceil(bundle_size) + 2 * k)
}

/// Bytes the dense-panel segment occupies in DRAM.
pub fn dense_panel_bytes(nrows: usize, k: usize, bundle_size: usize) -> usize {
    dense_panel_words(nrows, k, bundle_size) * WORD_BYTES
}

/// Serialize a flat bundle arena into the DRAM word layout — identical
/// output to [`serialize`] over the boxed form, with no per-bundle
/// indirection.
pub fn serialize_stream(s: &super::encode::BundleStream) -> Vec<u32> {
    let mut words = Vec::new();
    write_stream_words(s, &mut words);
    words
}

/// Append a flat bundle arena's word layout to `words` (reusable-buffer
/// variant of [`serialize_stream`]).
pub fn write_stream_words(s: &super::encode::BundleStream, words: &mut Vec<u32>) {
    words.reserve(stream_arena_words(s));
    for b in s.iter() {
        let count = b.cols.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        words.push((count << 8) | b.flags.0 as u32);
        words.push(b.shared);
        for (&d, &v) in b.cols.iter().zip(b.vals) {
            words.push(d);
            words.push(v.to_bits());
        }
        if b.flags.checksum() {
            let crc = crc32_words(&words[start..]);
            words.push(crc);
        }
    }
}

/// Number of 32-bit words a [`BundleStream`](super::encode::BundleStream)
/// occupies in DRAM once every bundle is checksummed: the plain layout
/// plus exactly one CRC32 word per bundle.
pub fn checksummed_stream_words(s: &super::encode::BundleStream) -> usize {
    3 * s.n_bundles() + 2 * s.n_elems()
}

/// Serialize a flat bundle arena with [`BundleFlags::CHECKSUM`] forced on
/// every bundle: each bundle's header carries the flag and is followed by
/// its CRC32 word (the fault-protected wire form of ARCHITECTURE.md §3.3).
/// Output length is exactly [`checksummed_stream_words`].
pub fn serialize_stream_checksummed(s: &super::encode::BundleStream) -> Vec<u32> {
    let mut words = Vec::with_capacity(checksummed_stream_words(s));
    for b in s.iter() {
        let count = b.cols.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        words.push((count << 8) | b.flags.with(BundleFlags::CHECKSUM).0 as u32);
        words.push(b.shared);
        for (&d, &v) in b.cols.iter().zip(b.vals) {
            words.push(d);
            words.push(v.to_bits());
        }
        let crc = crc32_words(&words[start..]);
        words.push(crc);
    }
    words
}

/// Streaming writer: encode a CSC matrix's bundle chains directly into the
/// flat word layout, one chain per column, recording words-per-column.
///
/// Functionally identical to `encode::csc_to_bundles` + [`serialize`] but
/// with no intermediate `Bundle` allocations — this is the actual Fig-3(d)
/// operation (the CPU writes bundles straight into the FPGA-visible DRAM
/// region) and it is on REAP's measured critical path (EXPERIMENTS.md
/// §Perf iteration 3).
pub fn write_csc_stream(
    m: &crate::sparse::Csc,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(m.ncols);
    for j in 0..m.ncols {
        let start = words.len();
        let rows = m.col_rows(j);
        let vals = m.col_vals(j);
        if rows.is_empty() {
            words.push(BundleFlags::END_OF_ROW as u32);
            words.push(j as u32);
        } else {
            let nchunks = rows.len().div_ceil(bundle_size);
            for ci in 0..nchunks {
                let lo = ci * bundle_size;
                let hi = ((ci + 1) * bundle_size).min(rows.len());
                let mut flags = 0u32;
                if ci + 1 == nchunks {
                    flags |= BundleFlags::END_OF_ROW as u32;
                }
                words.push((((hi - lo) as u32) << 8) | flags);
                words.push(j as u32);
                for k in lo..hi {
                    words.push(rows[k]);
                    words.push(vals[k].to_bits());
                }
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    // terminal flag on the very last bundle header of the stream
    mark_last_header_end_of_stream(words);
}

/// Streaming writer for Cholesky RL metadata chains (one per column of L):
/// `(row, start, end)` triples pointing into the row-major L storage map.
pub fn write_rl_stream(
    pattern: &crate::symbolic::LPattern,
    storage: &crate::symbolic::LStorageMap,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(pattern.n);
    for k in 0..pattern.n {
        let start = words.len();
        let rows = pattern.col_rows(k);
        let nchunks = rows.len().div_ceil(bundle_size).max(1);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(rows.len());
            let mut flags = BundleFlags::METADATA_ONLY as u32;
            if ci + 1 == nchunks {
                flags |= BundleFlags::END_OF_ROW as u32;
            }
            words.push((((hi - lo) as u32) << 8) | flags);
            words.push(k as u32);
            for &r in &rows[lo..hi] {
                words.push(r);
                words.push(storage.row_ptr[r as usize] as u32);
                words.push(storage.row_ptr[r as usize + 1] as u32);
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    mark_last_header_end_of_stream(words);
}

/// Walk the stream to its last bundle header and set `END_OF_STREAM`.
///
/// The header word participates in the per-bundle checksum, so a
/// checksummed last bundle has its CRC32 word recomputed after the flag
/// is set.
fn mark_last_header_end_of_stream(words: &mut Vec<u32>) {
    let mut p = 0usize;
    let mut last = None;
    while p < words.len() {
        let meta = words[p];
        let count = (meta >> 8) as usize;
        let flags = BundleFlags((meta & 0xff) as u8);
        let payload = if flags.metadata_only() { 3 * count } else { 2 * count };
        last = Some((p, payload, flags.checksum()));
        p += 2 + payload + usize::from(flags.checksum());
    }
    if let Some((h, payload, checksummed)) = last {
        words[h] |= BundleFlags::END_OF_STREAM as u32;
        if checksummed {
            words[h + 2 + payload] = crc32_words(&words[h..h + 2 + payload]);
        }
    }
}

/// Deserialize a flat word stream back into bundles, verifying per-bundle
/// checksums — trusted-caller wrapper over [`try_deserialize`].
pub fn deserialize(words: &[u32]) -> Result<Vec<Bundle>> {
    Ok(try_deserialize(words)?)
}

/// Deserialize a flat word stream back into bundles.
///
/// Total over arbitrary input: truncation, undersized payloads and CRC32
/// mismatches come back as structured [`RirError`]s; no input panics.
/// Checksummed bundles keep their `CHECKSUM` flag so re-serializing
/// reproduces the protected wire form bit-for-bit.
pub fn try_deserialize(words: &[u32]) -> std::result::Result<Vec<Bundle>, RirError> {
    let mut out = Vec::new();
    let mut p = 0usize;
    let mut bundle = 0usize;
    while p < words.len() {
        if p + 2 > words.len() {
            return Err(RirError::TruncatedHeader { word: p });
        }
        let meta = words[p];
        let shared = words[p + 1];
        let count = (meta >> 8) as usize;
        let flags = BundleFlags((meta & 0xff) as u8);
        let payload = if flags.metadata_only() { 3 * count } else { 2 * count };
        let need = payload + usize::from(flags.checksum());
        let have = words.len() - (p + 2);
        if need > have {
            return Err(RirError::TruncatedPayload { bundle, need, have });
        }
        if flags.checksum() {
            let stored = words[p + 2 + payload];
            let computed = crc32_words(&words[p..p + 2 + payload]);
            if stored != computed {
                return Err(RirError::ChecksumMismatch { bundle, stored, computed });
            }
        }
        p += 2;
        if flags.metadata_only() {
            let mut triples = Vec::with_capacity(count);
            for k in 0..count {
                triples.push(RlTriple {
                    row: words[p + 3 * k],
                    start: words[p + 3 * k + 1],
                    end: words[p + 3 * k + 2],
                });
            }
            // schedule() re-sets METADATA_ONLY; keep other flag bits
            out.push(Bundle::schedule(shared, triples, flags));
        } else {
            let mut distinct: Vec<Idx> = Vec::with_capacity(count);
            let mut values: Vec<Val> = Vec::with_capacity(count);
            for k in 0..count {
                distinct.push(words[p + 2 * k]);
                values.push(f32::from_bits(words[p + 2 * k + 1]));
            }
            out.push(Bundle::data(shared, distinct, values, flags));
        }
        p += need;
        bundle += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::encode::csr_to_bundles;
    use crate::sparse::gen;

    #[test]
    fn word_count_matches_serialized_length() {
        let m = gen::power_law(30, 500, 1);
        let bundles = csr_to_bundles(&m, 32);
        let words = serialize(&bundles);
        assert_eq!(words.len(), bundles.iter().map(bundle_words).sum::<usize>());
        assert_eq!(stream_bytes(&bundles), words.len() * WORD_BYTES);
    }

    #[test]
    fn stream_arena_serializes_identically() {
        let m = gen::power_law(30, 500, 4);
        for bs in [1usize, 8, 32] {
            let boxed = serialize(&csr_to_bundles(&m, bs));
            let arena = crate::rir::encode::BundleStream::from_csr(&m, bs);
            assert_eq!(serialize_stream(&arena), boxed, "bs {bs}");
            assert_eq!(stream_arena_words(&arena), boxed.len());
            assert_eq!(stream_arena_bytes(&arena), boxed.len() * WORD_BYTES);
        }
    }

    #[test]
    fn segment_words_partition_the_arena() {
        let m0 = gen::power_law(25, 300, 7);
        let m1 = gen::random_uniform(10, 10, 50, 8);
        let m2 = crate::sparse::Csr::new(0, 4);
        let mut s = crate::rir::encode::BundleStream::new();
        let bounds = s.encode_csr_jobs(&[&m0, &m1, &m2], 8);
        let total: usize = bounds
            .windows(2)
            .map(|w| segment_arena_words(&s, w[0], w[1]))
            .sum();
        assert_eq!(total, stream_arena_words(&s));
        assert_eq!(segment_arena_words(&s, bounds[2], bounds[3]), 0);
        // a segment's bytes equal the standalone encode's bytes
        let solo = crate::rir::encode::BundleStream::from_csr_with_threads(&m1, 8, 1);
        assert_eq!(
            segment_arena_bytes(&s, bounds[1], bounds[2]),
            stream_arena_bytes(&solo)
        );
    }

    #[test]
    fn dense_panel_words_match_real_encode() {
        let m = gen::power_law(20, 250, 9);
        for (k, bs) in [(4usize, 32usize), (8, 32), (7, 3), (0, 16)] {
            let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32 * 0.1).collect();
            let mut s = crate::rir::encode::BundleStream::new();
            let boundary = s.encode_csr_with_panel(&m, &x, k, bs);
            assert_eq!(
                segment_arena_words(&s, boundary, s.n_bundles()),
                dense_panel_words(m.ncols, k, bs),
                "k {k} bs {bs}"
            );
            // sparse prefix + panel segment partition the whole stream
            assert_eq!(
                segment_arena_words(&s, 0, boundary)
                    + segment_arena_words(&s, boundary, s.n_bundles()),
                stream_arena_words(&s)
            );
            // serialized length agrees with the arithmetic
            assert_eq!(serialize_stream(&s).len(), stream_arena_words(&s));
        }
    }

    /// Pins the word-layout formulas documented in ARCHITECTURE.md §"RIR
    /// wire format" — if this test moves, the spec must move with it.
    #[test]
    fn architecture_md_wire_format_accounting() {
        // data bundle: metadata word + shared word + 2 words per element
        let data = Bundle::data(7, vec![1, 2, 3], vec![0.5, 1.5, 2.5], BundleFlags::default());
        assert_eq!(bundle_words(&data), 2 + 2 * 3);
        // schedule (RL) bundle: metadata + shared + 3 words per triple
        let sched = Bundle::schedule(
            4,
            vec![RlTriple { row: 1, start: 0, end: 9 }; 2],
            BundleFlags::default(),
        );
        assert_eq!(bundle_words(&sched), 2 + 3 * 2);
        // metadata word packing: element count in bits 8.., flags in 0..8
        let words = serialize(std::slice::from_ref(&data));
        assert_eq!(words[0] >> 8, 3, "count field");
        assert_eq!(words[0] & 0xff, data.flags.0 as u32, "flags field");
        assert_eq!(words[1], 7, "shared-feature word");
        // value words are IEEE-754 bit patterns
        assert_eq!(words[3], 0.5f32.to_bits());
        // arena accounting: 2 words per bundle + 2 per element, 4 bytes/word
        let m = gen::power_law(15, 120, 2);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        assert_eq!(stream_arena_words(&s), 2 * s.n_bundles() + 2 * s.n_elems());
        assert_eq!(stream_arena_bytes(&s), stream_arena_words(&s) * 4);
        assert_eq!(WORD_BYTES, 4);

        // §3.3 checksummed form: CHECKSUM flag bit, +1 CRC32 word per
        // bundle, checksum taken over the bundle's preceding words
        assert_eq!(BundleFlags::CHECKSUM, 0b0001_0000);
        let ck = Bundle::data(
            7,
            vec![1, 2, 3],
            vec![0.5, 1.5, 2.5],
            BundleFlags::default().with(BundleFlags::CHECKSUM),
        );
        assert_eq!(bundle_words(&ck), 2 + 2 * 3 + 1);
        let ckw = serialize(std::slice::from_ref(&ck));
        assert_eq!(ckw.len(), bundle_words(&ck));
        assert_eq!(ckw[0] & 0xff, BundleFlags::CHECKSUM as u32, "flags field");
        assert_eq!(*ckw.last().unwrap(), crc32_words(&ckw[..ckw.len() - 1]));
        let cks = serialize_stream_checksummed(&s);
        assert_eq!(cks.len(), checksummed_stream_words(&s));
        assert_eq!(checksummed_stream_words(&s), 3 * s.n_bundles() + 2 * s.n_elems());
    }

    /// The CRC32 is the IEEE 802.3 / zlib `crc32` of the words'
    /// little-endian bytes — values pinned against an independent
    /// implementation.
    #[test]
    fn crc32_matches_reference_vectors() {
        assert_eq!(crc32_words(&[]), 0);
        assert_eq!(crc32_words(&[0x0102_0304]), 0xe951_a406);
        assert_eq!(crc32_words(&[0, 0, 0, 0]), 0xecbb_4b55);
        assert_eq!(crc32_words(&[0xdead_beef, 0x00c0_ffee]), 0x9f1d_caf9);
        // a fully worked checksummed data bundle, header included
        let b = Bundle::data(
            7,
            vec![2, 5, 9],
            vec![0.5, 1.5, -2.0],
            BundleFlags::default().with(BundleFlags::END_OF_ROW).with(BundleFlags::CHECKSUM),
        );
        let w = serialize(std::slice::from_ref(&b));
        assert_eq!(w[0], 0x311);
        assert_eq!(*w.last().unwrap(), 0xb3a6_a5bc);
    }

    #[test]
    fn checksummed_stream_roundtrips_and_detects_corruption() {
        let m = gen::power_law(22, 260, 6);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        let words = serialize_stream_checksummed(&s);
        // decode keeps CHECKSUM flags, so re-serializing is bit-identical
        let bundles = try_deserialize(&words).unwrap();
        assert!(bundles.iter().all(|b| b.flags.checksum()));
        assert_eq!(serialize(&bundles), words);
        // stripping the flags recovers the plain serialized form
        let plain: Vec<Bundle> = bundles
            .iter()
            .map(|b| Bundle {
                flags: BundleFlags(b.flags.0 & !BundleFlags::CHECKSUM),
                ..b.clone()
            })
            .collect();
        assert_eq!(serialize(&plain), serialize_stream(&s));
        // a corrupted shared-feature word is caught by the bundle's CRC
        let mut bad = words.clone();
        bad[1] ^= 1 << 17;
        match try_deserialize(&bad) {
            Err(RirError::ChecksumMismatch { bundle: 0, .. }) => {}
            other => panic!("expected checksum mismatch, got {other:?}"),
        }
        // dropping the CRC word of the last bundle truncates the stream
        let mut short = words;
        short.pop();
        assert!(matches!(
            try_deserialize(&short),
            Err(RirError::TruncatedPayload { .. })
        ));
    }

    #[test]
    fn end_of_stream_marker_recomputes_last_checksum() {
        // build a checksummed two-bundle stream by hand, then re-mark it
        let m = gen::random_uniform(6, 6, 18, 11);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 4);
        let mut words = serialize_stream_checksummed(&s);
        super::mark_last_header_end_of_stream(&mut words);
        let bundles = try_deserialize(&words).expect("marker must keep checksums valid");
        assert!(bundles.last().unwrap().flags.end_of_stream());
    }

    #[test]
    fn roundtrip_data_stream() {
        let m = gen::random_uniform(12, 40, 150, 2);
        let bundles = csr_to_bundles(&m, 8);
        let words = serialize(&bundles);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, bundles);
    }

    #[test]
    fn roundtrip_schedule_bundle() {
        let b = Bundle::schedule(
            5,
            vec![
                RlTriple { row: 1, start: 0, end: 9 },
                RlTriple { row: 7, start: 9, end: 12 },
            ],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let words = serialize(std::slice::from_ref(&b));
        assert_eq!(words.len(), 2 + 3 * 2);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, vec![b]);
    }

    #[test]
    fn nan_values_survive_bit_roundtrip() {
        let b = Bundle::data(
            0,
            vec![1],
            vec![f32::NAN],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let back = deserialize(&serialize(std::slice::from_ref(&b))).unwrap();
        assert!(back[0].values()[0].is_nan());
    }

    #[test]
    fn truncated_stream_rejected() {
        let m = gen::random_uniform(3, 3, 6, 3);
        let mut words = serialize(&csr_to_bundles(&m, 32));
        words.pop();
        assert!(deserialize(&words).is_err());
    }

    #[test]
    fn empty_stream_is_empty() {
        assert_eq!(deserialize(&[]).unwrap(), Vec::<Bundle>::new());
    }
}
