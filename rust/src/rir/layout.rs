//! The DRAM word layout of a bundle stream (paper Fig 3(d) / §IV).
//!
//! The FPGA's read controller consumes bundles as a flat sequence of 32-bit
//! words: a metadata word (element count, flags), the shared-feature word,
//! then the distinct/value pairs. The write controller produces the same
//! layout in reverse order per §IV ("It reads the metadata first, shared
//! feature next, and finally the distinct elements").
//!
//! This module is both the wire format (serialize/deserialize, used by the
//! runtime tests and the `gen-stream` CLI) and the **byte accounting** the
//! DRAM bandwidth model charges for each bundle.

use anyhow::{bail, ensure, Result};

use crate::sparse::{Idx, Val};

use super::bundle::{Bundle, BundleFlags, Payload, RlTriple};

/// Bytes per stream word (the design streams 32-bit index + 32-bit f32).
pub const WORD_BYTES: usize = 4;

/// Number of 32-bit words a bundle occupies in DRAM.
///
/// metadata word + shared word + payload (2 words per data pair, 3 words
/// per schedule triple).
pub fn bundle_words(b: &Bundle) -> usize {
    2 + match &b.payload {
        Payload::Data { distinct, .. } => 2 * distinct.len(),
        Payload::Schedule { triples } => 3 * triples.len(),
    }
}

/// Bytes a bundle occupies in DRAM.
pub fn bundle_bytes(b: &Bundle) -> usize {
    bundle_words(b) * WORD_BYTES
}

/// Total bytes of a bundle stream.
pub fn stream_bytes(bundles: &[Bundle]) -> usize {
    bundles.iter().map(bundle_bytes).sum()
}

/// Serialize a bundle stream to the flat word layout.
pub fn serialize(bundles: &[Bundle]) -> Vec<u32> {
    let mut words = Vec::with_capacity(bundles.iter().map(bundle_words).sum());
    for b in bundles {
        let count = b.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let meta = (count << 8) | b.flags.0 as u32;
        words.push(meta);
        words.push(b.shared);
        match &b.payload {
            Payload::Data { distinct, values } => {
                for (&d, &v) in distinct.iter().zip(values) {
                    words.push(d);
                    words.push(v.to_bits());
                }
            }
            Payload::Schedule { triples } => {
                for t in triples {
                    words.push(t.row);
                    words.push(t.start);
                    words.push(t.end);
                }
            }
        }
    }
    words
}

/// Number of 32-bit words a [`BundleStream`](super::encode::BundleStream)
/// occupies in DRAM (all bundles are data bundles: 2 header words + 2 per
/// element).
pub fn stream_arena_words(s: &super::encode::BundleStream) -> usize {
    2 * s.n_bundles() + 2 * s.n_elems()
}

/// Bytes a [`BundleStream`](super::encode::BundleStream) occupies in DRAM.
pub fn stream_arena_bytes(s: &super::encode::BundleStream) -> usize {
    stream_arena_words(s) * WORD_BYTES
}

/// Number of 32-bit words bundles `[lo, hi)` of a stream arena occupy in
/// DRAM — one job's segment of a multi-tenant stream (see
/// [`super::encode::BundleStream::encode_csr_jobs`]). Summing every job's
/// segment reproduces [`stream_arena_words`] exactly.
pub fn segment_arena_words(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    assert!(lo <= hi && hi <= s.n_bundles(), "segment [{lo}, {hi}) out of bounds");
    2 * (hi - lo) + 2 * (s.off[hi] - s.off[lo])
}

/// Bytes bundles `[lo, hi)` of a stream arena occupy in DRAM.
pub fn segment_arena_bytes(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    segment_arena_words(s, lo, hi) * WORD_BYTES
}

/// Number of 32-bit words the dense-panel segment of an SpMM stream
/// occupies in DRAM (see
/// [`BundleStream::encode_csr_with_panel`](super::encode::BundleStream::encode_csr_with_panel)):
/// one chain per panel row, `ceil(k / bundle_size)` bundles per chain at
/// 2 header words each, plus 2 words per element — the same data-bundle
/// layout as the sparse stream, `k` elements per row. Zero when `k == 0`
/// (a zero-width panel contributes no bundles). Cross-checked against the
/// real encoder in the tests below.
pub fn dense_panel_words(nrows: usize, k: usize, bundle_size: usize) -> usize {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if k == 0 {
        return 0;
    }
    nrows * (2 * k.div_ceil(bundle_size) + 2 * k)
}

/// Bytes the dense-panel segment occupies in DRAM.
pub fn dense_panel_bytes(nrows: usize, k: usize, bundle_size: usize) -> usize {
    dense_panel_words(nrows, k, bundle_size) * WORD_BYTES
}

/// Serialize a flat bundle arena into the DRAM word layout — identical
/// output to [`serialize`] over the boxed form, with no per-bundle
/// indirection.
pub fn serialize_stream(s: &super::encode::BundleStream) -> Vec<u32> {
    let mut words = Vec::new();
    write_stream_words(s, &mut words);
    words
}

/// Append a flat bundle arena's word layout to `words` (reusable-buffer
/// variant of [`serialize_stream`]).
pub fn write_stream_words(s: &super::encode::BundleStream, words: &mut Vec<u32>) {
    words.reserve(stream_arena_words(s));
    for b in s.iter() {
        let count = b.cols.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        words.push((count << 8) | b.flags.0 as u32);
        words.push(b.shared);
        for (&d, &v) in b.cols.iter().zip(b.vals) {
            words.push(d);
            words.push(v.to_bits());
        }
    }
}

/// Streaming writer: encode a CSC matrix's bundle chains directly into the
/// flat word layout, one chain per column, recording words-per-column.
///
/// Functionally identical to `encode::csc_to_bundles` + [`serialize`] but
/// with no intermediate `Bundle` allocations — this is the actual Fig-3(d)
/// operation (the CPU writes bundles straight into the FPGA-visible DRAM
/// region) and it is on REAP's measured critical path (EXPERIMENTS.md
/// §Perf iteration 3).
pub fn write_csc_stream(
    m: &crate::sparse::Csc,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(m.ncols);
    for j in 0..m.ncols {
        let start = words.len();
        let rows = m.col_rows(j);
        let vals = m.col_vals(j);
        if rows.is_empty() {
            words.push(BundleFlags::END_OF_ROW as u32);
            words.push(j as u32);
        } else {
            let nchunks = rows.len().div_ceil(bundle_size);
            for ci in 0..nchunks {
                let lo = ci * bundle_size;
                let hi = ((ci + 1) * bundle_size).min(rows.len());
                let mut flags = 0u32;
                if ci + 1 == nchunks {
                    flags |= BundleFlags::END_OF_ROW as u32;
                }
                words.push((((hi - lo) as u32) << 8) | flags);
                words.push(j as u32);
                for k in lo..hi {
                    words.push(rows[k]);
                    words.push(vals[k].to_bits());
                }
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    // terminal flag on the very last bundle header of the stream
    mark_last_header_end_of_stream(words);
}

/// Streaming writer for Cholesky RL metadata chains (one per column of L):
/// `(row, start, end)` triples pointing into the row-major L storage map.
pub fn write_rl_stream(
    pattern: &crate::symbolic::LPattern,
    storage: &crate::symbolic::LStorageMap,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(pattern.n);
    for k in 0..pattern.n {
        let start = words.len();
        let rows = pattern.col_rows(k);
        let nchunks = rows.len().div_ceil(bundle_size).max(1);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(rows.len());
            let mut flags = BundleFlags::METADATA_ONLY as u32;
            if ci + 1 == nchunks {
                flags |= BundleFlags::END_OF_ROW as u32;
            }
            words.push((((hi - lo) as u32) << 8) | flags);
            words.push(k as u32);
            for &r in &rows[lo..hi] {
                words.push(r);
                words.push(storage.row_ptr[r as usize] as u32);
                words.push(storage.row_ptr[r as usize + 1] as u32);
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    mark_last_header_end_of_stream(words);
}

/// Walk the stream to its last bundle header and set `END_OF_STREAM`.
fn mark_last_header_end_of_stream(words: &mut Vec<u32>) {
    let mut p = 0usize;
    let mut last_header = None;
    while p < words.len() {
        last_header = Some(p);
        let meta = words[p];
        let count = (meta >> 8) as usize;
        let flags = BundleFlags((meta & 0xff) as u8);
        p += 2 + if flags.metadata_only() { 3 * count } else { 2 * count };
    }
    if let Some(h) = last_header {
        words[h] |= BundleFlags::END_OF_STREAM as u32;
    }
}

/// Deserialize a flat word stream back into bundles.
pub fn deserialize(words: &[u32]) -> Result<Vec<Bundle>> {
    let mut out = Vec::new();
    let mut p = 0usize;
    while p < words.len() {
        ensure!(p + 2 <= words.len(), "truncated bundle header at word {p}");
        let meta = words[p];
        let shared = words[p + 1];
        p += 2;
        let count = (meta >> 8) as usize;
        let flags = BundleFlags((meta & 0xff) as u8);
        if flags.metadata_only() {
            ensure!(p + 3 * count <= words.len(), "truncated schedule payload");
            let mut triples = Vec::with_capacity(count);
            for k in 0..count {
                triples.push(RlTriple {
                    row: words[p + 3 * k],
                    start: words[p + 3 * k + 1],
                    end: words[p + 3 * k + 2],
                });
            }
            p += 3 * count;
            // schedule() re-sets METADATA_ONLY; keep other flag bits
            out.push(Bundle::schedule(shared, triples, flags));
        } else {
            ensure!(p + 2 * count <= words.len(), "truncated data payload");
            let mut distinct: Vec<Idx> = Vec::with_capacity(count);
            let mut values: Vec<Val> = Vec::with_capacity(count);
            for k in 0..count {
                distinct.push(words[p + 2 * k]);
                values.push(f32::from_bits(words[p + 2 * k + 1]));
            }
            p += 2 * count;
            out.push(Bundle::data(shared, distinct, values, flags));
        }
    }
    if p != words.len() {
        bail!("trailing garbage after last bundle");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::encode::csr_to_bundles;
    use crate::sparse::gen;

    #[test]
    fn word_count_matches_serialized_length() {
        let m = gen::power_law(30, 500, 1);
        let bundles = csr_to_bundles(&m, 32);
        let words = serialize(&bundles);
        assert_eq!(words.len(), bundles.iter().map(bundle_words).sum::<usize>());
        assert_eq!(stream_bytes(&bundles), words.len() * WORD_BYTES);
    }

    #[test]
    fn stream_arena_serializes_identically() {
        let m = gen::power_law(30, 500, 4);
        for bs in [1usize, 8, 32] {
            let boxed = serialize(&csr_to_bundles(&m, bs));
            let arena = crate::rir::encode::BundleStream::from_csr(&m, bs);
            assert_eq!(serialize_stream(&arena), boxed, "bs {bs}");
            assert_eq!(stream_arena_words(&arena), boxed.len());
            assert_eq!(stream_arena_bytes(&arena), boxed.len() * WORD_BYTES);
        }
    }

    #[test]
    fn segment_words_partition_the_arena() {
        let m0 = gen::power_law(25, 300, 7);
        let m1 = gen::random_uniform(10, 10, 50, 8);
        let m2 = crate::sparse::Csr::new(0, 4);
        let mut s = crate::rir::encode::BundleStream::new();
        let bounds = s.encode_csr_jobs(&[&m0, &m1, &m2], 8);
        let total: usize = bounds
            .windows(2)
            .map(|w| segment_arena_words(&s, w[0], w[1]))
            .sum();
        assert_eq!(total, stream_arena_words(&s));
        assert_eq!(segment_arena_words(&s, bounds[2], bounds[3]), 0);
        // a segment's bytes equal the standalone encode's bytes
        let solo = crate::rir::encode::BundleStream::from_csr_with_threads(&m1, 8, 1);
        assert_eq!(
            segment_arena_bytes(&s, bounds[1], bounds[2]),
            stream_arena_bytes(&solo)
        );
    }

    #[test]
    fn dense_panel_words_match_real_encode() {
        let m = gen::power_law(20, 250, 9);
        for (k, bs) in [(4usize, 32usize), (8, 32), (7, 3), (0, 16)] {
            let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32 * 0.1).collect();
            let mut s = crate::rir::encode::BundleStream::new();
            let boundary = s.encode_csr_with_panel(&m, &x, k, bs);
            assert_eq!(
                segment_arena_words(&s, boundary, s.n_bundles()),
                dense_panel_words(m.ncols, k, bs),
                "k {k} bs {bs}"
            );
            // sparse prefix + panel segment partition the whole stream
            assert_eq!(
                segment_arena_words(&s, 0, boundary)
                    + segment_arena_words(&s, boundary, s.n_bundles()),
                stream_arena_words(&s)
            );
            // serialized length agrees with the arithmetic
            assert_eq!(serialize_stream(&s).len(), stream_arena_words(&s));
        }
    }

    /// Pins the word-layout formulas documented in ARCHITECTURE.md §"RIR
    /// wire format" — if this test moves, the spec must move with it.
    #[test]
    fn architecture_md_wire_format_accounting() {
        // data bundle: metadata word + shared word + 2 words per element
        let data = Bundle::data(7, vec![1, 2, 3], vec![0.5, 1.5, 2.5], BundleFlags::default());
        assert_eq!(bundle_words(&data), 2 + 2 * 3);
        // schedule (RL) bundle: metadata + shared + 3 words per triple
        let sched = Bundle::schedule(
            4,
            vec![RlTriple { row: 1, start: 0, end: 9 }; 2],
            BundleFlags::default(),
        );
        assert_eq!(bundle_words(&sched), 2 + 3 * 2);
        // metadata word packing: element count in bits 8.., flags in 0..8
        let words = serialize(std::slice::from_ref(&data));
        assert_eq!(words[0] >> 8, 3, "count field");
        assert_eq!(words[0] & 0xff, data.flags.0 as u32, "flags field");
        assert_eq!(words[1], 7, "shared-feature word");
        // value words are IEEE-754 bit patterns
        assert_eq!(words[3], 0.5f32.to_bits());
        // arena accounting: 2 words per bundle + 2 per element, 4 bytes/word
        let m = gen::power_law(15, 120, 2);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        assert_eq!(stream_arena_words(&s), 2 * s.n_bundles() + 2 * s.n_elems());
        assert_eq!(stream_arena_bytes(&s), stream_arena_words(&s) * 4);
        assert_eq!(WORD_BYTES, 4);
    }

    #[test]
    fn roundtrip_data_stream() {
        let m = gen::random_uniform(12, 40, 150, 2);
        let bundles = csr_to_bundles(&m, 8);
        let words = serialize(&bundles);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, bundles);
    }

    #[test]
    fn roundtrip_schedule_bundle() {
        let b = Bundle::schedule(
            5,
            vec![
                RlTriple { row: 1, start: 0, end: 9 },
                RlTriple { row: 7, start: 9, end: 12 },
            ],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let words = serialize(std::slice::from_ref(&b));
        assert_eq!(words.len(), 2 + 3 * 2);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, vec![b]);
    }

    #[test]
    fn nan_values_survive_bit_roundtrip() {
        let b = Bundle::data(
            0,
            vec![1],
            vec![f32::NAN],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let back = deserialize(&serialize(std::slice::from_ref(&b))).unwrap();
        assert!(back[0].values()[0].is_nan());
    }

    #[test]
    fn truncated_stream_rejected() {
        let m = gen::random_uniform(3, 3, 6, 3);
        let mut words = serialize(&csr_to_bundles(&m, 32));
        words.pop();
        assert!(deserialize(&words).is_err());
    }

    #[test]
    fn empty_stream_is_empty() {
        assert_eq!(deserialize(&[]).unwrap(), Vec::<Bundle>::new());
    }
}
