//! RIR — the REAP Intermediate Representation (paper §II, Figs 2–4).
//!
//! RIR is the contract between the CPU (Layer 3, this crate) and the FPGA
//! (simulated datapath + AOT-compiled XLA arithmetic). A **bundle**
//! co-locates a *shared feature* (row index for CSR sources, column index
//! for CSC sources) with up to `bundle_size` *(distinct feature, value)*
//! pairs, plus metadata: the element count, an end-of-row marker for rows
//! split across bundles, and — for Cholesky — *metadata-only* bundles that
//! carry pure scheduling information (`RL` triples telling the FPGA where
//! each needed row of L lives in its memory).
//!
//! * [`bundle`] — the bundle type and flags (including the SpMM
//!   dense-panel flag).
//! * [`encode`] — CSR/CSC → bundles (including big-row splitting); the
//!   hot path is the allocation-free [`encode::BundleStream`] SoA arena.
//!   Three stream shapes exist: single-matrix, job-segmented
//!   (multi-tenant, [`encode::BundleStream::encode_csr_jobs`]) and
//!   sparse + dense-panel (SpMM,
//!   [`encode::BundleStream::encode_csr_with_panel`]).
//! * [`decode`] — bundles → CSR (the paper's `decompress` routine), plus
//!   per-tenant segment extraction and dense-panel reassembly.
//! * [`layout`] — the flat DRAM word stream of Fig 3(d) and its byte
//!   accounting (drives the simulator's bandwidth model), including the
//!   optional per-bundle CRC32 word behind [`BundleFlags::CHECKSUM`].
//! * [`error`] — the typed [`RirError`] the fallible `try_*` stream
//!   decoders return for malformed, truncated or checksum-failing input.
//! * [`schedule`] — wave scheduling of bundles onto pipelines (the CPU's
//!   "scheduling decisions" of Fig 3), single-job and multi-tenant
//!   batched.
//!
//! The serialized word layout, the arena invariants and the wave-schedule
//! invariants (monotone B-streams, bit-identical decompose/replay,
//! thread-invariance) are specified in `ARCHITECTURE.md` — the
//! wire-format section is cross-checked against this module's byte
//! accounting by `layout`'s unit tests.

pub mod bundle;
pub mod decode;
pub mod encode;
pub mod error;
pub mod layout;
pub mod schedule;

pub use bundle::{Bundle, BundleFlags, Payload, RlTriple, DEFAULT_BUNDLE_SIZE};
pub use encode::{BundleRef, BundleStream};
pub use error::RirError;
pub use schedule::{BatchSchedule, BatchSegment, BatchWave, SpgemmSchedule, Wave};
