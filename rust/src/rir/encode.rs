//! The paper's `compress` routine: standard sparse formats → RIR bundles.
//!
//! "When the number of non-zero elements in a row exceeds the RIR bundle
//! size, CPU breaks the whole row into multiple bundles" (§III-A). The last
//! chunk of each row carries `END_OF_ROW`; an empty row still emits one
//! empty end-of-row bundle so the consumer's row counter stays aligned.
//!
//! The hot encode path produces a [`BundleStream`] — a flat
//! structure-of-arrays arena where every bundle is an *extent* into shared
//! `cols`/`vals` buffers. The per-bundle `Vec` clones of the original
//! [`Bundle`]-based encoder made preprocessing allocation-bound on
//! low-degree matrices (EXPERIMENTS.md §Perf); the arena performs **zero
//! per-bundle heap allocations** (buffers are sized once up front and
//! retained across [`BundleStream::clear`] for steady-state reuse). The
//! boxed [`Bundle`] API remains as the ergonomic/interchange form and is
//! produced from the arena via [`BundleStream::to_bundles`].

use crate::sparse::{Csc, Csr, Idx, Val};
use crate::util::{grains, preprocess_threads};

use super::bundle::{Bundle, BundleFlags};

/// A flat SoA arena of data bundles: bundle `i` is
/// `(shared[i], flags[i], cols[off[i]..off[i+1]], vals[off[i]..off[i+1]])`.
///
/// For whole-matrix encodes the element arrays are an exact copy of the
/// source CSR/CSC element arrays (bundling only inserts *boundaries*), so
/// the arena is as close to zero-copy as a materialized stream can be.
#[derive(Clone, Debug, PartialEq)]
pub struct BundleStream {
    /// Shared feature per bundle (row index for CSR, column for CSC).
    pub shared: Vec<Idx>,
    /// Flags per bundle.
    pub flags: Vec<BundleFlags>,
    /// Element extents: bundle `i` owns `cols[off[i]..off[i+1]]`.
    /// Always `n_bundles() + 1` entries, `off[0] == 0`.
    pub off: Vec<usize>,
    /// Distinct features of all bundles, concatenated.
    pub cols: Vec<Idx>,
    /// Values of all bundles, concatenated.
    pub vals: Vec<Val>,
}

/// A borrowed view of one bundle in a [`BundleStream`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BundleRef<'a> {
    pub shared: Idx,
    pub flags: BundleFlags,
    pub cols: &'a [Idx],
    pub vals: &'a [Val],
}

impl Default for BundleStream {
    fn default() -> Self {
        Self::new()
    }
}

impl BundleStream {
    /// Empty stream.
    pub fn new() -> Self {
        BundleStream {
            shared: Vec::new(),
            flags: Vec::new(),
            off: vec![0],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Number of bundles.
    pub fn n_bundles(&self) -> usize {
        self.shared.len()
    }

    /// True when the stream carries no bundles.
    pub fn is_empty(&self) -> bool {
        self.shared.is_empty()
    }

    /// Total elements across all bundles.
    pub fn n_elems(&self) -> usize {
        self.cols.len()
    }

    /// Borrowed view of bundle `i`.
    #[inline]
    pub fn bundle(&self, i: usize) -> BundleRef<'_> {
        let (lo, hi) = (self.off[i], self.off[i + 1]);
        BundleRef {
            shared: self.shared[i],
            flags: self.flags[i],
            cols: &self.cols[lo..hi],
            vals: &self.vals[lo..hi],
        }
    }

    /// Iterate bundles in stream order.
    pub fn iter(&self) -> impl Iterator<Item = BundleRef<'_>> + '_ {
        (0..self.n_bundles()).map(move |i| self.bundle(i))
    }

    /// Reset to empty, retaining every buffer's capacity (the reuse hook
    /// that makes repeated encodes allocation-free in steady state).
    pub fn clear(&mut self) {
        self.shared.clear();
        self.flags.clear();
        self.off.clear();
        self.off.push(0);
        self.cols.clear();
        self.vals.clear();
    }

    /// Append one bundle by copying its extent into the arena.
    #[inline]
    fn push_bundle(&mut self, shared: Idx, cols: &[Idx], vals: &[Val], flags: BundleFlags) {
        debug_assert_eq!(cols.len(), vals.len());
        self.shared.push(shared);
        self.flags.push(flags);
        self.cols.extend_from_slice(cols);
        self.vals.extend_from_slice(vals);
        self.off.push(self.cols.len());
    }

    /// Append one row/column chain: ≤`bundle_size` chunks, `END_OF_ROW` on
    /// the last; an empty chain still emits one empty end-of-row bundle.
    fn push_chain(&mut self, shared: Idx, cols: &[Idx], vals: &[Val], bundle_size: usize) {
        if cols.is_empty() {
            self.push_bundle(
                shared,
                &[],
                &[],
                BundleFlags::default().with(BundleFlags::END_OF_ROW),
            );
            return;
        }
        let nchunks = cols.len().div_ceil(bundle_size);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(cols.len());
            let mut flags = BundleFlags::default();
            if ci + 1 == nchunks {
                flags = flags.with(BundleFlags::END_OF_ROW);
            }
            self.push_bundle(shared, &cols[lo..hi], &vals[lo..hi], flags);
        }
    }

    /// Set `END_OF_STREAM` on the final bundle (if any).
    fn mark_end_of_stream(&mut self) {
        if let Some(last) = self.flags.last_mut() {
            *last = last.with(BundleFlags::END_OF_STREAM);
        }
    }

    /// Encode a CSR matrix into this stream (cleared first): one chain per
    /// row, shared feature = row index, `END_OF_STREAM` on the last bundle.
    pub fn encode_csr(&mut self, m: &Csr, bundle_size: usize) {
        assert!(bundle_size > 0, "bundle_size must be positive");
        self.clear();
        self.reserve_for(chain_bundle_count_csr(m, bundle_size), m.nnz());
        for i in 0..m.nrows {
            self.push_chain(i as Idx, m.row_cols(i), m.row_vals(i), bundle_size);
        }
        self.mark_end_of_stream();
    }

    /// Encode a CSC matrix into this stream (cleared first): one chain per
    /// column, shared feature = column index.
    pub fn encode_csc(&mut self, m: &Csc, bundle_size: usize) {
        assert!(bundle_size > 0, "bundle_size must be positive");
        self.clear();
        let nb: usize = (0..m.ncols)
            .map(|j| m.col_nnz(j).div_ceil(bundle_size).max(1))
            .sum();
        self.reserve_for(nb, m.nnz());
        for j in 0..m.ncols {
            self.push_chain(j as Idx, m.col_rows(j), m.col_vals(j), bundle_size);
        }
        self.mark_end_of_stream();
    }

    /// Encode N independent CSR jobs into this shared arena (cleared
    /// first), returning the per-job *bundle boundaries* — `n_jobs + 1`
    /// ascending indices, first 0, last [`Self::n_bundles`]. Job `j` owns
    /// bundles `bounds[j]..bounds[j+1]`; its last bundle carries
    /// `END_OF_STREAM`, so every segment is a self-contained stream and
    /// [`super::decode::stream_segment_to_csr`] can extract one tenant's
    /// matrix without touching the others. An empty job (no rows) owns an
    /// empty bundle range.
    pub fn encode_csr_jobs(&mut self, jobs: &[&Csr], bundle_size: usize) -> Vec<usize> {
        assert!(bundle_size > 0, "bundle_size must be positive");
        self.clear();
        let nb: usize = jobs
            .iter()
            .map(|m| chain_bundle_count_csr(m, bundle_size))
            .sum();
        let ne: usize = jobs.iter().map(|m| m.nnz()).sum();
        self.reserve_for(nb, ne);
        let mut bounds = Vec::with_capacity(jobs.len() + 1);
        bounds.push(0usize);
        for m in jobs {
            let before = self.n_bundles();
            for i in 0..m.nrows {
                self.push_chain(i as Idx, m.row_cols(i), m.row_vals(i), bundle_size);
            }
            if self.n_bundles() > before {
                self.mark_end_of_stream();
            }
            bounds.push(self.n_bundles());
        }
        bounds
    }

    /// Encode a CSR matrix **plus a dense right-hand-side panel** into this
    /// stream (cleared first) — the SpMM input layout: A's bundle chains
    /// first (exactly [`Self::encode_csr`]), then one `DENSE_PANEL` chain
    /// per row of X (shared feature = X row index, distinct features =
    /// lane indices `0..k`), `END_OF_STREAM` on the stream's final bundle.
    ///
    /// `x` is row-major `m.ncols × k` (`x[r*k + j]` is row `r`, lane `j`).
    /// Returns the bundle index where the panel segment begins, so callers
    /// can address the sparse prefix `0..boundary` and the panel segment
    /// `boundary..n_bundles()` independently (the same segment discipline
    /// as [`Self::encode_csr_jobs`]). Sparse decoders skip panel bundles;
    /// [`super::decode::stream_panel_to_dense`] reassembles X from the
    /// segment. A `k == 0` panel contributes no bundles.
    pub fn encode_csr_with_panel(
        &mut self,
        m: &Csr,
        x: &[Val],
        k: usize,
        bundle_size: usize,
    ) -> usize {
        assert!(bundle_size > 0, "bundle_size must be positive");
        assert_eq!(x.len(), m.ncols * k, "X panel shape mismatch");
        self.clear();
        let panel_chains = if k == 0 { 0 } else { m.ncols };
        let nb = chain_bundle_count_csr(m, bundle_size)
            + panel_chains * k.div_ceil(bundle_size.max(1)).max(1);
        self.reserve_for(nb, m.nnz() + m.ncols * k);
        for i in 0..m.nrows {
            self.push_chain(i as Idx, m.row_cols(i), m.row_vals(i), bundle_size);
        }
        let boundary = self.n_bundles();
        if k > 0 {
            // lane indices are shared by every panel row chain
            let lanes: Vec<Idx> = (0..k as Idx).collect();
            for r in 0..m.ncols {
                let before = self.n_bundles();
                self.push_chain(r as Idx, &lanes, &x[r * k..(r + 1) * k], bundle_size);
                for f in &mut self.flags[before..] {
                    *f = f.with(BundleFlags::DENSE_PANEL);
                }
            }
        }
        self.mark_end_of_stream();
        boundary
    }

    /// Encode only the selected rows of a CSR matrix, in the given order
    /// (cleared first) — the SpGEMM scheduler's B-row stream of a wave
    /// (paper Fig 3(d)). No `END_OF_STREAM`: wave streams concatenate.
    pub fn encode_csr_rows(&mut self, m: &Csr, rows: &[Idx], bundle_size: usize) {
        assert!(bundle_size > 0, "bundle_size must be positive");
        self.clear();
        let nb: usize = rows
            .iter()
            .map(|&r| m.row_nnz(r as usize).div_ceil(bundle_size).max(1))
            .sum();
        let ne: usize = rows.iter().map(|&r| m.row_nnz(r as usize)).sum();
        self.reserve_for(nb, ne);
        for &r in rows {
            let i = r as usize;
            self.push_chain(r, m.row_cols(i), m.row_vals(i), bundle_size);
        }
    }

    fn reserve_for(&mut self, bundles: usize, elems: usize) {
        self.shared.reserve(bundles);
        self.flags.reserve(bundles);
        self.off.reserve(bundles);
        self.cols.reserve(elems);
        self.vals.reserve(elems);
    }

    /// Fresh stream from a CSR matrix (default worker count).
    pub fn from_csr(m: &Csr, bundle_size: usize) -> Self {
        Self::from_csr_with_threads(m, bundle_size, preprocess_threads())
    }

    /// Fresh stream from a CSR matrix, encoded in parallel over row
    /// grains claimed through the deterministic work-stealing executor
    /// ([`crate::util::grains`]). Bit-identical to the serial encode for
    /// every thread count.
    pub fn from_csr_with_threads(m: &Csr, bundle_size: usize, nthreads: usize) -> Self {
        let nthreads = nthreads.max(1);
        Self::from_csr_with_grain(
            m,
            bundle_size,
            nthreads,
            grains::default_grain(m.nrows, nthreads),
        )
    }

    /// [`Self::from_csr_with_threads`] with an explicit row-grain size
    /// (the grain-size invariance knob for the property suite).
    ///
    /// Each grain encodes into its pre-split slice of the output arrays
    /// (bundle and element extents are computed up front from `row_ptr`),
    /// so the merged stream is a pure function of the grain order — no
    /// post-join copy, no ordering race.
    pub fn from_csr_with_grain(
        m: &Csr,
        bundle_size: usize,
        nthreads: usize,
        grain: usize,
    ) -> Self {
        assert!(bundle_size > 0, "bundle_size must be positive");
        let nthreads = nthreads.clamp(1, m.nrows.max(1));
        if nthreads <= 1 || m.nrows < 2 * nthreads {
            let mut s = BundleStream::new();
            s.encode_csr(m, bundle_size);
            return s;
        }

        let n_grains = grains::grain_count(m.nrows, grain);
        let grain_bundles: Vec<usize> = (0..n_grains)
            .map(|g| {
                let (lo, hi) = grains::grain_span(g, grain, m.nrows);
                (lo..hi)
                    .map(|i| m.row_nnz(i).div_ceil(bundle_size).max(1))
                    .sum()
            })
            .collect();
        let nb: usize = grain_bundles.iter().sum();
        let nnz = m.nnz();

        let mut shared = vec![0 as Idx; nb];
        let mut flags = vec![BundleFlags::default(); nb];
        let mut off = vec![0usize; nb + 1];
        let mut cols = vec![0 as Idx; nnz];
        let mut vals = vec![0 as Val; nnz];

        {
            // pre-split each output array into per-grain slots; a worker
            // takes grain g's slot when it claims grain g. Every slot is
            // taken exactly once, so the per-slot lock is never contended
            // — it exists only to hand the mutable slices across threads.
            let mut slots: Vec<std::sync::Mutex<Option<GrainOut<'_>>>> =
                Vec::with_capacity(n_grains);
            let mut sh_rest = shared.as_mut_slice();
            let mut fl_rest = flags.as_mut_slice();
            let mut off_rest = &mut off[1..]; // off[0] stays 0
            let mut cols_rest = cols.as_mut_slice();
            let mut vals_rest = vals.as_mut_slice();
            for (g, &nb_g) in grain_bundles.iter().enumerate() {
                let (r_lo, r_hi) = grains::grain_span(g, grain, m.nrows);
                let ne_g = m.row_ptr[r_hi] - m.row_ptr[r_lo];
                let (sh, sh_r) = std::mem::take(&mut sh_rest).split_at_mut(nb_g);
                let (fl, fl_r) = std::mem::take(&mut fl_rest).split_at_mut(nb_g);
                let (of, of_r) = std::mem::take(&mut off_rest).split_at_mut(nb_g);
                let (co, co_r) = std::mem::take(&mut cols_rest).split_at_mut(ne_g);
                let (va, va_r) = std::mem::take(&mut vals_rest).split_at_mut(ne_g);
                sh_rest = sh_r;
                fl_rest = fl_r;
                off_rest = of_r;
                cols_rest = co_r;
                vals_rest = va_r;
                slots.push(std::sync::Mutex::new(Some(GrainOut {
                    shared: sh,
                    flags: fl,
                    off: of,
                    cols: co,
                    vals: va,
                })));
            }
            let slots = &slots;
            grains::run_grains(m.nrows, grain, nthreads, |g, r_lo, r_hi| {
                let out = slots[g]
                    .lock()
                    .expect("grain slot lock poisoned")
                    .take()
                    .expect("grain slot taken exactly once");
                encode_band(
                    m,
                    bundle_size,
                    r_lo,
                    r_hi,
                    out.shared,
                    out.flags,
                    out.off,
                    out.cols,
                    out.vals,
                );
            });
        }

        let mut s = BundleStream { shared, flags, off, cols, vals };
        s.mark_end_of_stream();
        s
    }

    /// Fresh stream from a CSC matrix.
    pub fn from_csc(m: &Csc, bundle_size: usize) -> Self {
        let mut s = BundleStream::new();
        s.encode_csc(m, bundle_size);
        s
    }

    /// Convert to the boxed [`Bundle`] interchange form (allocates per
    /// bundle — convenience/compat, not the hot path).
    pub fn to_bundles(&self) -> Vec<Bundle> {
        self.iter()
            .map(|b| Bundle::data(b.shared, b.cols.to_vec(), b.vals.to_vec(), b.flags))
            .collect()
    }
}

/// Bundle count for the whole-CSR encode (one chain per row, empty rows
/// still emit one bundle).
pub(crate) fn chain_bundle_count_csr(m: &Csr, bundle_size: usize) -> usize {
    (0..m.nrows)
        .map(|i| m.row_nnz(i).div_ceil(bundle_size).max(1))
        .sum()
}

/// One grain's pre-split slices of the parallel encode's output arrays
/// (see [`BundleStream::from_csr_with_grain`]).
struct GrainOut<'a> {
    shared: &'a mut [Idx],
    flags: &'a mut [BundleFlags],
    off: &'a mut [usize],
    cols: &'a mut [Idx],
    vals: &'a mut [Val],
}

/// Encode rows `[r_lo, r_hi)` into pre-split output slices. `off` holds the
/// *global* element offsets of the band's bundle ends (`off[j]` = end of the
/// band's j-th bundle), matching the serial encode exactly.
#[allow(clippy::too_many_arguments)]
fn encode_band(
    m: &Csr,
    bundle_size: usize,
    r_lo: usize,
    r_hi: usize,
    shared: &mut [Idx],
    flags: &mut [BundleFlags],
    off: &mut [usize],
    cols: &mut [Idx],
    vals: &mut [Val],
) {
    let elem_base = m.row_ptr[r_lo];
    let mut b = 0usize; // bundle cursor within the band
    let mut e = 0usize; // element cursor within the band
    for i in r_lo..r_hi {
        let rcols = m.row_cols(i);
        let rvals = m.row_vals(i);
        if rcols.is_empty() {
            shared[b] = i as Idx;
            flags[b] = BundleFlags::default().with(BundleFlags::END_OF_ROW);
            off[b] = elem_base + e;
            b += 1;
            continue;
        }
        let nchunks = rcols.len().div_ceil(bundle_size);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(rcols.len());
            shared[b] = i as Idx;
            flags[b] = if ci + 1 == nchunks {
                BundleFlags::default().with(BundleFlags::END_OF_ROW)
            } else {
                BundleFlags::default()
            };
            cols[e..e + hi - lo].copy_from_slice(&rcols[lo..hi]);
            vals[e..e + hi - lo].copy_from_slice(&rvals[lo..hi]);
            e += hi - lo;
            off[b] = elem_base + e;
            b += 1;
        }
    }
    debug_assert_eq!(b, shared.len());
    debug_assert_eq!(e, cols.len());
}

/// Encode one row's worth of (cols, vals) into ≤`bundle_size` chunks,
/// appending to `out`. Shared feature is the row index.
fn encode_row(
    out: &mut Vec<Bundle>,
    shared: Idx,
    cols: &[Idx],
    vals: &[f32],
    bundle_size: usize,
) {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if cols.is_empty() {
        out.push(Bundle::data(
            shared,
            Vec::new(),
            Vec::new(),
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        ));
        return;
    }
    let nchunks = cols.len().div_ceil(bundle_size);
    for (ci, (cchunk, vchunk)) in cols
        .chunks(bundle_size)
        .zip(vals.chunks(bundle_size))
        .enumerate()
    {
        let mut flags = BundleFlags::default();
        if ci + 1 == nchunks {
            flags = flags.with(BundleFlags::END_OF_ROW);
        }
        out.push(Bundle::data(shared, cchunk.to_vec(), vchunk.to_vec(), flags));
    }
}

/// CSR → RIR: one bundle chain per row, shared feature = row index
/// (paper Fig 2(b), CSR case). The final bundle gets `END_OF_STREAM`.
pub fn csr_to_bundles(m: &Csr, bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::with_capacity(m.nrows + m.nnz() / bundle_size.max(1));
    for i in 0..m.nrows {
        encode_row(&mut out, i as Idx, m.row_cols(i), m.row_vals(i), bundle_size);
    }
    if let Some(last) = out.last_mut() {
        last.flags = last.flags.with(BundleFlags::END_OF_STREAM);
    }
    out
}

/// CSC → RIR: one bundle chain per column, shared feature = column index
/// (paper Fig 2(b), CSC case; distinct features are row indices).
pub fn csc_to_bundles(m: &Csc, bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::with_capacity(m.ncols + m.nnz() / bundle_size.max(1));
    for j in 0..m.ncols {
        encode_row(&mut out, j as Idx, m.col_rows(j), m.col_vals(j), bundle_size);
    }
    if let Some(last) = out.last_mut() {
        last.flags = last.flags.with(BundleFlags::END_OF_STREAM);
    }
    out
}

/// Encode only the selected rows of a CSR matrix, in the given order —
/// used by the SpGEMM scheduler to lay out the B-row stream of a wave
/// (paper Fig 3(d): "rows of B necessary to produce all partial products").
pub fn csr_rows_to_bundles(m: &Csr, rows: &[Idx], bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::new();
    for &r in rows {
        let i = r as usize;
        encode_row(&mut out, r, m.row_cols(i), m.row_vals(i), bundle_size);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn small_rows_single_bundle() {
        let m = gen::random_uniform(10, 10, 40, 1);
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 10); // every row fits one bundle
        for (i, b) in bundles.iter().enumerate() {
            assert_eq!(b.shared as usize, i);
            assert!(b.flags.end_of_row());
            assert_eq!(b.distinct(), m.row_cols(i));
        }
        assert!(bundles.last().unwrap().flags.end_of_stream());
        assert!(!bundles[0].flags.end_of_stream());
    }

    #[test]
    fn big_row_splits_with_end_marker_on_last() {
        // one row with 70 nnz -> chunks of 32/32/6
        let m = gen::random_uniform(1, 100, 70, 2);
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 3);
        assert_eq!(bundles[0].len(), 32);
        assert_eq!(bundles[1].len(), 32);
        assert_eq!(bundles[2].len(), 6);
        assert!(!bundles[0].flags.end_of_row());
        assert!(!bundles[1].flags.end_of_row());
        assert!(bundles[2].flags.end_of_row());
    }

    #[test]
    fn empty_row_emits_empty_end_of_row_bundle() {
        let mut m = crate::sparse::Csr::new(3, 3);
        m.row_ptr = vec![0, 0, 0, 0];
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 3);
        assert!(bundles.iter().all(|b| b.is_empty() && b.flags.end_of_row()));
    }

    #[test]
    fn csc_uses_column_as_shared() {
        let m = gen::random_uniform(6, 6, 12, 3).to_csc();
        let bundles = csc_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 6);
        for (j, b) in bundles.iter().enumerate() {
            assert_eq!(b.shared as usize, j);
            assert_eq!(b.distinct(), m.col_rows(j));
        }
    }

    #[test]
    fn selected_rows_in_given_order() {
        let m = gen::random_uniform(8, 8, 24, 4);
        let order = [5 as Idx, 1, 5];
        let bundles = csr_rows_to_bundles(&m, &order, 32);
        assert_eq!(bundles.len(), 3);
        assert_eq!(bundles[0].shared, 5);
        assert_eq!(bundles[1].shared, 1);
        assert_eq!(bundles[2].shared, 5); // re-streaming the same row is legal
    }

    #[test]
    fn bundle_size_one_degenerates_to_elements() {
        let m = gen::random_uniform(2, 10, 6, 5);
        let bundles = csr_to_bundles(&m, 1);
        assert_eq!(bundles.len(), 6);
        assert!(bundles.iter().all(|b| b.len() == 1));
    }

    // ---- BundleStream arena ----

    #[test]
    fn stream_matches_boxed_encoder_csr() {
        for seed in 0..4u64 {
            let m = gen::power_law(40, 700, seed);
            for bs in [1usize, 7, 32] {
                let s = BundleStream::from_csr_with_threads(&m, bs, 1);
                assert_eq!(s.to_bundles(), csr_to_bundles(&m, bs), "seed {seed} bs {bs}");
            }
        }
    }

    #[test]
    fn stream_matches_boxed_encoder_csc() {
        let m = gen::random_uniform(15, 25, 140, 6).to_csc();
        let s = BundleStream::from_csc(&m, 8);
        assert_eq!(s.to_bundles(), csc_to_bundles(&m, 8));
    }

    #[test]
    fn stream_rows_matches_boxed_encoder() {
        let m = gen::random_uniform(8, 8, 24, 4);
        let order = [5 as Idx, 1, 5];
        let mut s = BundleStream::new();
        s.encode_csr_rows(&m, &order, 32);
        assert_eq!(s.to_bundles(), csr_rows_to_bundles(&m, &order, 32));
    }

    #[test]
    fn parallel_encode_bit_identical() {
        let m = gen::power_law(200, 4000, 7);
        let base = BundleStream::from_csr_with_threads(&m, 16, 1);
        for t in [2usize, 3, 4, 8] {
            assert_eq!(BundleStream::from_csr_with_threads(&m, 16, t), base, "t={t}");
            for grain in [1usize, 4, 1 << 20] {
                assert_eq!(
                    BundleStream::from_csr_with_grain(&m, 16, t, grain),
                    base,
                    "t={t} grain={grain}"
                );
            }
        }
    }

    #[test]
    fn parallel_encode_handles_empty_and_big_rows() {
        // rows: empty, 70-nnz (splits), empty, small
        let mut m = crate::sparse::Csr::new(4, 100);
        let big: Vec<u32> = (0..70).collect();
        m.cols = big.iter().copied().chain([3, 9]).collect();
        m.vals = vec![1.0; 72];
        m.row_ptr = vec![0, 0, 70, 70, 72];
        m.validate().unwrap();
        let base = BundleStream::from_csr_with_threads(&m, 32, 1);
        for t in [2usize, 4] {
            assert_eq!(BundleStream::from_csr_with_threads(&m, 32, t), base);
        }
        assert_eq!(base.to_bundles(), csr_to_bundles(&m, 32));
    }

    #[test]
    fn stream_elements_are_exact_copy_of_csr_arrays() {
        let m = gen::banded_fem(50, 600, 8);
        let s = BundleStream::from_csr(&m, 32);
        assert_eq!(s.cols, m.cols);
        assert_eq!(s.vals, m.vals);
        assert_eq!(*s.off.last().unwrap(), m.nnz());
    }

    #[test]
    fn clear_retains_capacity_for_reuse() {
        let m = gen::random_uniform(30, 30, 300, 9);
        let mut s = BundleStream::new();
        s.encode_csr(&m, 8);
        let caps = (s.shared.capacity(), s.cols.capacity());
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.off, vec![0]);
        s.encode_csr(&m, 8);
        assert!(s.shared.capacity() >= caps.0 && s.cols.capacity() >= caps.1);
    }

    #[test]
    fn empty_matrix_stream() {
        let m = crate::sparse::Csr::new(0, 0);
        let s = BundleStream::from_csr(&m, 32);
        assert!(s.is_empty());
        assert_eq!(s.to_bundles(), Vec::<Bundle>::new());
    }

    // ---- job-segmented (multi-tenant) streams ----

    #[test]
    fn job_segments_concatenate_per_job_encodes() {
        let m0 = gen::power_law(20, 200, 11);
        let m1 = gen::random_uniform(8, 15, 40, 12);
        let m2 = crate::sparse::Csr::new(0, 5); // empty job
        let m3 = gen::banded_fem(12, 80, 13);
        let jobs = [&m0, &m1, &m2, &m3];
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&jobs, 16);
        assert_eq!(bounds.len(), 5);
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), s.n_bundles());
        assert_eq!(bounds[2], bounds[3], "empty job owns an empty range");
        // each segment is exactly the job's standalone encode
        for (j, m) in jobs.iter().enumerate() {
            let solo = csr_to_bundles(m, 16);
            let seg: Vec<Bundle> = (bounds[j]..bounds[j + 1])
                .map(|i| {
                    let b = s.bundle(i);
                    Bundle::data(b.shared, b.cols.to_vec(), b.vals.to_vec(), b.flags)
                })
                .collect();
            assert_eq!(seg, solo, "job {j}");
        }
        // every non-empty segment terminates with END_OF_STREAM
        for j in 0..jobs.len() {
            if bounds[j] < bounds[j + 1] {
                assert!(s.bundle(bounds[j + 1] - 1).flags.end_of_stream(), "job {j}");
            }
        }
    }

    // ---- dense-panel (SpMM) streams ----

    #[test]
    fn panel_segment_follows_sparse_prefix() {
        let m = gen::power_law(12, 120, 31);
        let k = 5usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, k, 8);
        // sparse prefix is exactly the plain CSR encode (minus the stream
        // terminator, which moved to the panel's last bundle)
        let mut plain = BundleStream::new();
        plain.encode_csr(&m, 8);
        assert_eq!(boundary, plain.n_bundles());
        for i in 0..boundary {
            let (got, want) = (s.bundle(i), plain.bundle(i));
            assert!(!got.flags.dense_panel(), "sparse bundle {i} mis-flagged");
            assert_eq!(got.shared, want.shared);
            assert_eq!(got.cols, want.cols);
            assert_eq!(got.vals, want.vals);
            assert_eq!(got.flags.end_of_row(), want.flags.end_of_row());
            assert!(!got.flags.end_of_stream());
        }
        // panel segment: one chain per X row, lanes 0..k, flagged
        let mut r = 0usize;
        for i in boundary..s.n_bundles() {
            let b = s.bundle(i);
            assert!(b.flags.dense_panel(), "panel bundle {i} unflagged");
            assert_eq!(b.shared as usize, r);
            if b.flags.end_of_row() {
                r += 1;
            }
        }
        assert_eq!(r, m.ncols, "one panel chain per X row");
        assert!(s.bundle(s.n_bundles() - 1).flags.end_of_stream());
    }

    #[test]
    fn panel_rows_split_when_k_exceeds_bundle() {
        let m = gen::random_uniform(4, 6, 10, 32);
        let k = 7usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| (i % 9) as f32).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, k, 3); // 3+3+1 per row
        let panel_bundles = s.n_bundles() - boundary;
        assert_eq!(panel_bundles, m.ncols * 3);
        for r in 0..m.ncols {
            let b = s.bundle(boundary + 3 * r);
            assert_eq!(b.cols, &[0, 1, 2]);
            assert_eq!(b.vals, &x[r * k..r * k + 3]);
            assert!(!b.flags.end_of_row());
            assert!(s.bundle(boundary + 3 * r + 2).flags.end_of_row());
        }
    }

    #[test]
    fn zero_width_panel_degenerates_to_plain_encode() {
        let m = gen::power_law(10, 80, 33);
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &[], 0, 16);
        let mut plain = BundleStream::new();
        plain.encode_csr(&m, 16);
        assert_eq!(boundary, s.n_bundles());
        assert_eq!(s, plain);
    }

    #[test]
    fn empty_matrix_with_panel_is_panel_only() {
        let m = crate::sparse::Csr::new(0, 4);
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, 2, 16);
        assert_eq!(boundary, 0);
        assert_eq!(s.n_bundles(), 4);
        assert!(s.iter().all(|b| b.flags.dense_panel()));
        assert!(s.bundle(3).flags.end_of_stream());
    }

    #[test]
    fn job_segments_of_one_job_match_whole_encode() {
        let m = gen::power_law(30, 400, 14);
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&[&m], 8);
        assert_eq!(bounds, vec![0, s.n_bundles()]);
        assert_eq!(s, BundleStream::from_csr_with_threads(&m, 8, 1));
    }
}
