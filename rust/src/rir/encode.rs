//! The paper's `compress` routine: standard sparse formats → RIR bundles.
//!
//! "When the number of non-zero elements in a row exceeds the RIR bundle
//! size, CPU breaks the whole row into multiple bundles" (§III-A). The last
//! chunk of each row carries `END_OF_ROW`; an empty row still emits one
//! empty end-of-row bundle so the consumer's row counter stays aligned.

use crate::sparse::{Csc, Csr, Idx};

use super::bundle::{Bundle, BundleFlags};

/// Encode one row's worth of (cols, vals) into ≤`bundle_size` chunks,
/// appending to `out`. Shared feature is the row index.
fn encode_row(
    out: &mut Vec<Bundle>,
    shared: Idx,
    cols: &[Idx],
    vals: &[f32],
    bundle_size: usize,
) {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if cols.is_empty() {
        out.push(Bundle::data(
            shared,
            Vec::new(),
            Vec::new(),
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        ));
        return;
    }
    let nchunks = cols.len().div_ceil(bundle_size);
    for (ci, (cchunk, vchunk)) in cols
        .chunks(bundle_size)
        .zip(vals.chunks(bundle_size))
        .enumerate()
    {
        let mut flags = BundleFlags::default();
        if ci + 1 == nchunks {
            flags = flags.with(BundleFlags::END_OF_ROW);
        }
        out.push(Bundle::data(shared, cchunk.to_vec(), vchunk.to_vec(), flags));
    }
}

/// CSR → RIR: one bundle chain per row, shared feature = row index
/// (paper Fig 2(b), CSR case). The final bundle gets `END_OF_STREAM`.
pub fn csr_to_bundles(m: &Csr, bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::with_capacity(m.nrows + m.nnz() / bundle_size.max(1));
    for i in 0..m.nrows {
        encode_row(&mut out, i as Idx, m.row_cols(i), m.row_vals(i), bundle_size);
    }
    if let Some(last) = out.last_mut() {
        last.flags = last.flags.with(BundleFlags::END_OF_STREAM);
    }
    out
}

/// CSC → RIR: one bundle chain per column, shared feature = column index
/// (paper Fig 2(b), CSC case; distinct features are row indices).
pub fn csc_to_bundles(m: &Csc, bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::with_capacity(m.ncols + m.nnz() / bundle_size.max(1));
    for j in 0..m.ncols {
        encode_row(&mut out, j as Idx, m.col_rows(j), m.col_vals(j), bundle_size);
    }
    if let Some(last) = out.last_mut() {
        last.flags = last.flags.with(BundleFlags::END_OF_STREAM);
    }
    out
}

/// Encode only the selected rows of a CSR matrix, in the given order —
/// used by the SpGEMM scheduler to lay out the B-row stream of a wave
/// (paper Fig 3(d): "rows of B necessary to produce all partial products").
pub fn csr_rows_to_bundles(m: &Csr, rows: &[Idx], bundle_size: usize) -> Vec<Bundle> {
    let mut out = Vec::new();
    for &r in rows {
        let i = r as usize;
        encode_row(&mut out, r, m.row_cols(i), m.row_vals(i), bundle_size);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn small_rows_single_bundle() {
        let m = gen::random_uniform(10, 10, 40, 1);
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 10); // every row fits one bundle
        for (i, b) in bundles.iter().enumerate() {
            assert_eq!(b.shared as usize, i);
            assert!(b.flags.end_of_row());
            assert_eq!(b.distinct(), m.row_cols(i));
        }
        assert!(bundles.last().unwrap().flags.end_of_stream());
        assert!(!bundles[0].flags.end_of_stream());
    }

    #[test]
    fn big_row_splits_with_end_marker_on_last() {
        // one row with 70 nnz -> chunks of 32/32/6
        let m = gen::random_uniform(1, 100, 70, 2);
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 3);
        assert_eq!(bundles[0].len(), 32);
        assert_eq!(bundles[1].len(), 32);
        assert_eq!(bundles[2].len(), 6);
        assert!(!bundles[0].flags.end_of_row());
        assert!(!bundles[1].flags.end_of_row());
        assert!(bundles[2].flags.end_of_row());
    }

    #[test]
    fn empty_row_emits_empty_end_of_row_bundle() {
        let mut m = crate::sparse::Csr::new(3, 3);
        m.row_ptr = vec![0, 0, 0, 0];
        let bundles = csr_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 3);
        assert!(bundles.iter().all(|b| b.is_empty() && b.flags.end_of_row()));
    }

    #[test]
    fn csc_uses_column_as_shared() {
        let m = gen::random_uniform(6, 6, 12, 3).to_csc();
        let bundles = csc_to_bundles(&m, 32);
        assert_eq!(bundles.len(), 6);
        for (j, b) in bundles.iter().enumerate() {
            assert_eq!(b.shared as usize, j);
            assert_eq!(b.distinct(), m.col_rows(j));
        }
    }

    #[test]
    fn selected_rows_in_given_order() {
        let m = gen::random_uniform(8, 8, 24, 4);
        let order = [5 as Idx, 1, 5];
        let bundles = csr_rows_to_bundles(&m, &order, 32);
        assert_eq!(bundles.len(), 3);
        assert_eq!(bundles[0].shared, 5);
        assert_eq!(bundles[1].shared, 1);
        assert_eq!(bundles[2].shared, 5); // re-streaming the same row is legal
    }

    #[test]
    fn bundle_size_one_degenerates_to_elements() {
        let m = gen::random_uniform(2, 10, 6, 5);
        let bundles = csr_to_bundles(&m, 1);
        assert_eq!(bundles.len(), 6);
        assert!(bundles.iter().all(|b| b.len() == 1));
    }
}
