//! The CPU's scheduling pass for SpGEMM (paper Fig 3).
//!
//! "CPU is aware of the number of parallel pipelines in the FPGA to
//! properly perform the scheduling task. Each pipeline processes a row of
//! A. Hence, it has laid out the rows of A followed by all the rows of B
//! necessary to produce all partial products."
//!
//! The schedule groups A-row *chunks* (≤ bundle size, big rows split per
//! §III-A) into **waves** of at most `pipelines` chunks. For each wave the
//! CPU computes the set of B-rows that must be streamed — the union of the
//! column indices of the wave's A elements, deduplicated and sorted so the
//! FPGA sees a monotone DRAM address pattern.
//!
//! The pass is sharded across the deterministic work-stealing executor
//! ([`crate::util::grains`], ARCHITECTURE.md §10): chunk enumeration is a
//! cheap serial prologue, then workers claim fixed-size *wave-range
//! grains* from a shared cursor (stealing from other runs once their own
//! drains), each reusing its own `mark` scratch across the waves it
//! claims. Because a wave's B-stream depends only on its own assignments
//! and grain results merge in grain order, the result is bit-identical to
//! the serial one for every thread count *and* grain size
//! (property-tested in `tests/prop_invariants.rs`). The static
//! element-balanced banding this replaces is kept callable
//! ([`schedule_spgemm_static_bands`]) for the `reap bench scaling`
//! side-by-side and for diff tests against the pinned banding behavior.
//! Each wave also records its measured CPU cost, which drives the
//! per-wave CPU/FPGA pipelining model in [`crate::coordinator::overlap`]
//! (see EXPERIMENTS.md §Perf).

use std::time::Instant;

use crate::fpga::ConfigError;
use crate::sparse::{Csr, Idx, Val};
use crate::util::{grains, preprocess_threads};

use super::layout::WORD_BYTES;

/// One pipeline's work for a wave: a chunk of a row of A (loaded into the
/// pipeline's CAM as `column index → value`).
///
/// Zero-copy: the chunk is identified by its extent in the source CSR's
/// element arrays (cloning per-chunk vectors made preprocessing dominate
/// end-to-end time on low-degree matrices — see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    /// Source row of A.
    pub a_row: Idx,
    /// Chunk ordinal within the row (0-based).
    pub chunk: u32,
    /// True for the last chunk of its row — the pipeline emits the merged
    /// row segment downstream when this chunk completes.
    pub last_chunk: bool,
    /// Start offset of the chunk in the CSR `cols`/`vals` arrays.
    pub start: usize,
    /// Chunk length (≤ bundle size).
    pub len: usize,
}

impl Assignment {
    /// Column indices of the chunk (the CAM keys).
    #[inline]
    pub fn a_cols<'a>(&self, a: &'a Csr) -> &'a [Idx] {
        &a.cols[self.start..self.start + self.len]
    }

    /// Values of the chunk.
    #[inline]
    pub fn a_vals<'a>(&self, a: &'a Csr) -> &'a [Val] {
        &a.vals[self.start..self.start + self.len]
    }
}

/// One scheduling wave: ≤ `pipelines` assignments plus the B-row stream
/// they share.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Wave {
    pub assignments: Vec<Assignment>,
    /// B-rows broadcast to all pipelines this wave (ascending, deduped).
    pub b_rows: Vec<Idx>,
}

/// The complete SpGEMM schedule plus DRAM traffic accounting.
#[derive(Clone, Debug)]
pub struct SpgemmSchedule {
    pub pipelines: usize,
    pub bundle_size: usize,
    pub waves: Vec<Wave>,
    /// Words of A-side bundles streamed (each chunk: 2 header + 2/elem).
    pub a_words: usize,
    /// Words of B-side bundles streamed, summed over waves (B rows are
    /// re-streamed per wave that needs them — the row-by-row formulation's
    /// cost, paper §III-A "the B-matrix is streamed into the FPGA for each
    /// row of A").
    pub b_words: usize,
    /// Measured CPU seconds of the chunk-enumeration prologue (cannot
    /// overlap FPGA compute — it precedes the first wave).
    pub prep_cpu_s: f64,
    /// Measured CPU seconds spent building each wave, normalized so the
    /// sum equals the wall-clock of the wave-building phase (under the
    /// worker pool the raw per-wave durations overlap in time). Drives
    /// [`crate::coordinator::overlap::pipelined_total`].
    pub wave_cpu_s: Vec<f64>,
}

impl SpgemmSchedule {
    /// Bytes of input streamed into the FPGA.
    pub fn input_bytes(&self) -> usize {
        (self.a_words + self.b_words) * WORD_BYTES
    }

    /// Number of waves.
    pub fn n_waves(&self) -> usize {
        self.waves.len()
    }

    /// Total A chunks scheduled.
    pub fn n_chunks(&self) -> usize {
        self.waves.iter().map(|w| w.assignments.len()).sum()
    }

    /// Total measured CPU seconds of the pass (prologue + all waves).
    pub fn cpu_total_s(&self) -> f64 {
        self.prep_cpu_s + self.wave_cpu_s.iter().sum::<f64>()
    }
}

/// Words to stream one bundle-chain of a row with `nnz` elements.
pub(crate) fn row_stream_words(nnz: usize, bundle_size: usize) -> usize {
    let chunks = nnz.div_ceil(bundle_size).max(1);
    2 * chunks + 2 * nnz
}

/// Shared geometry gate for the schedulers. Zero-valued geometry is
/// rejected with the same typed [`ConfigError`] that
/// [`FpgaConfig::validate`](crate::fpga::FpgaConfig::validate) returns,
/// so callers handle one error surface for configuration problems.
fn scheduling_geometry(pipelines: usize, bundle_size: usize) -> Result<(), ConfigError> {
    if pipelines == 0 {
        return Err(ConfigError::ZeroPipelines);
    }
    if bundle_size == 0 {
        return Err(ConfigError::ZeroBundleSize);
    }
    Ok(())
}

/// How the wave-building phase distributes waves across workers. Both
/// modes produce bit-identical schedules; they differ only in how badly
/// a skewed wave-cost distribution can serialize the pass.
#[derive(Clone, Copy, Debug)]
enum WaveExec {
    /// Deterministic work-stealing over fixed-size wave-range grains
    /// (`None` picks [`grains::default_grain`]). The default.
    Steal(Option<usize>),
    /// The retired static element-balanced banding
    /// ([`band_bounds_by_elems`]), kept for the scaling comparison.
    StaticBands,
}

// ---------------------------------------------------------------------------
// Multi-tenant batched scheduling (many small SpGEMMs sharing one design)
// ---------------------------------------------------------------------------

/// One job's slice of a shared wave's B-side stream: the job id plus the
/// B-rows streamed for that job's assignments in the wave (ascending,
/// deduped — the same contract as [`Wave::b_rows`], per job).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchSegment {
    pub job: u32,
    pub b_rows: Vec<Idx>,
}

/// One shared scheduling wave across independent jobs: ≤ `pipelines`
/// job-tagged assignments plus one B-stream segment per job present.
///
/// Assignments are job-major (chunks keep their within-job order), so a
/// job occupies one contiguous run per wave and `segments` mirrors the
/// run order exactly.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchWave {
    /// `(job, assignment)` pairs, ≤ `pipelines` of them.
    pub assignments: Vec<(u32, Assignment)>,
    /// Per-job B-row segments, in run (job-ascending) order.
    pub segments: Vec<BatchSegment>,
}

/// The complete shared-wave schedule for N independent SpGEMM jobs, plus
/// DRAM traffic accounting summed across jobs.
///
/// Invariant (property-tested): extracting job *j*'s assignments in wave
/// order yields exactly the chunk sequence of the single-job
/// [`schedule_spgemm`] for that job — batching changes only the wave
/// grouping, never the per-job chunk identity or order. That makes a
/// batched run bit-identical to N independent scheduled runs.
#[derive(Clone, Debug)]
pub struct BatchSchedule {
    pub pipelines: usize,
    pub bundle_size: usize,
    pub n_jobs: usize,
    pub waves: Vec<BatchWave>,
    /// Words of A-side bundles streamed, summed over jobs.
    pub a_words: usize,
    /// Words of B-side bundles streamed, summed over waves and segments.
    pub b_words: usize,
    /// Measured CPU seconds of the chunk-enumeration prologue.
    pub prep_cpu_s: f64,
    /// Measured CPU seconds per wave, normalized to the phase wall clock
    /// (same convention as [`SpgemmSchedule::wave_cpu_s`]).
    pub wave_cpu_s: Vec<f64>,
}

impl BatchSchedule {
    /// Bytes of input streamed into the FPGA across all jobs.
    pub fn input_bytes(&self) -> usize {
        (self.a_words + self.b_words) * WORD_BYTES
    }

    /// Number of shared waves.
    pub fn n_waves(&self) -> usize {
        self.waves.len()
    }

    /// Total A chunks scheduled across all jobs.
    pub fn n_chunks(&self) -> usize {
        self.waves.iter().map(|w| w.assignments.len()).sum()
    }

    /// Total measured CPU seconds of the pass.
    pub fn cpu_total_s(&self) -> f64 {
        self.prep_cpu_s + self.wave_cpu_s.iter().sum::<f64>()
    }

    /// Fraction of pipeline slots filled across the schedule — the
    /// packing quality the batcher exists to maximize (time-weighting
    /// happens in the simulator; this is the schedule-level view).
    pub fn slot_occupancy(&self) -> f64 {
        if self.waves.is_empty() {
            return 0.0;
        }
        self.n_chunks() as f64 / (self.n_waves() * self.pipelines) as f64
    }

    /// Extract each job's assignment sequence in wave order — by the batch
    /// invariant this is exactly the job's single-job chunk order. Shared
    /// by [`Self::decompose`] and the numeric replay
    /// ([`crate::coordinator::batch::numeric_batch`]).
    pub fn per_job_assignments(&self) -> Vec<Vec<Assignment>> {
        let mut per_job: Vec<Vec<Assignment>> = vec![Vec::new(); self.n_jobs];
        for w in &self.waves {
            for &(j, asg) in &w.assignments {
                per_job[j as usize].push(asg);
            }
        }
        per_job
    }

    /// Reconstruct the N single-job schedules this batch packs: job *j*'s
    /// assignments are extracted in wave order and regrouped into waves of
    /// `pipelines` chunks, with per-wave B-streams and traffic recomputed
    /// from the job's matrices. The result must equal
    /// [`schedule_spgemm`]`(a_j, b_j, …)` wave-for-wave (timings are
    /// zeroed — they were spent once, on the shared pass).
    pub fn decompose(&self, jobs: &[(Csr, Csr)]) -> Vec<SpgemmSchedule> {
        assert_eq!(jobs.len(), self.n_jobs, "job list does not match schedule");
        self.per_job_assignments()
            .into_iter()
            .zip(jobs)
            .map(|(chunks, (a, b))| {
                let a_words: usize = chunks.iter().map(|c| 2 + 2 * c.len).sum();
                let n_waves = chunks.len().div_ceil(self.pipelines);
                let mut waves = Vec::with_capacity(n_waves);
                let mut b_words = 0usize;
                for wid in 0..n_waves {
                    let lo = wid * self.pipelines;
                    let hi = ((wid + 1) * self.pipelines).min(chunks.len());
                    let mut b_rows: Vec<Idx> = Vec::new();
                    for asg in &chunks[lo..hi] {
                        b_rows.extend_from_slice(asg.a_cols(a));
                    }
                    b_rows.sort_unstable();
                    b_rows.dedup();
                    for &r in &b_rows {
                        b_words += row_stream_words(b.row_nnz(r as usize), self.bundle_size);
                    }
                    waves.push(Wave { assignments: chunks[lo..hi].to_vec(), b_rows });
                }
                SpgemmSchedule {
                    pipelines: self.pipelines,
                    bundle_size: self.bundle_size,
                    waves,
                    a_words,
                    b_words,
                    prep_cpu_s: 0.0,
                    wave_cpu_s: vec![0.0; n_waves],
                }
            })
            .collect()
    }
}

/// Build the shared-wave schedule for N independent jobs `C_j = A_j × B_j`
/// with the default worker count.
///
/// Panics on zero-valued geometry; use [`try_schedule_spgemm_batch`] for
/// the typed rejection.
pub fn schedule_spgemm_batch(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
) -> BatchSchedule {
    schedule_spgemm_batch_with_threads(jobs, pipelines, bundle_size, preprocess_threads())
}

/// Fallible form of [`schedule_spgemm_batch`]: rejects `pipelines == 0` /
/// `bundle_size == 0` with the typed [`ConfigError`] instead of panicking.
pub fn try_schedule_spgemm_batch(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
) -> Result<BatchSchedule, ConfigError> {
    try_schedule_spgemm_batch_with_threads(jobs, pipelines, bundle_size, preprocess_threads())
}

/// Build the shared-wave schedule for N independent jobs on `nthreads`
/// workers.
///
/// Chunks are enumerated job-major (job 0's rows, then job 1's, …), so
/// each job's chunk order is exactly the single-job order; shared waves
/// are then filled greedily with `pipelines` chunks regardless of job
/// boundaries — that is the packing that keeps wide designs busy on many
/// small jobs. The result is identical for every `nthreads` ≥ 1.
pub fn schedule_spgemm_batch_with_threads(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> BatchSchedule {
    match try_schedule_spgemm_batch_with_threads(jobs, pipelines, bundle_size, nthreads) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

/// Fallible form of [`schedule_spgemm_batch_with_threads`] (see
/// [`try_schedule_spgemm_batch`]).
pub fn try_schedule_spgemm_batch_with_threads(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> Result<BatchSchedule, ConfigError> {
    schedule_batch_core(jobs, pipelines, bundle_size, nthreads, WaveExec::Steal(None))
}

/// [`schedule_spgemm_batch_with_threads`] with an explicit grain size for
/// the work-stealing executor. Output is grain-size-invariant
/// (property-tested); the knob exists for those tests and for tuning
/// experiments.
///
/// Panics on zero-valued geometry or `grain == 0`.
pub fn schedule_spgemm_batch_with_grain(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
    grain: usize,
) -> BatchSchedule {
    match schedule_batch_core(jobs, pipelines, bundle_size, nthreads, WaveExec::Steal(Some(grain)))
    {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

/// Static-banded predecessor of [`schedule_spgemm_batch_with_threads`],
/// kept callable for the `reap bench scaling` comparison. Bit-identical
/// output, different (skew-sensitive) load balance.
///
/// Panics on zero-valued geometry.
pub fn schedule_spgemm_batch_static_bands(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> BatchSchedule {
    match schedule_batch_core(jobs, pipelines, bundle_size, nthreads, WaveExec::StaticBands) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

fn schedule_batch_core(
    jobs: &[(Csr, Csr)],
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
    exec: WaveExec,
) -> Result<BatchSchedule, ConfigError> {
    scheduling_geometry(pipelines, bundle_size)?;

    // ---- prologue: enumerate chunks job-major, in row order ----
    let t_prep = Instant::now();
    let mut chunks: Vec<(u32, Assignment)> = Vec::new();
    let mut a_words = 0usize;
    for (j, (a, b)) in jobs.iter().enumerate() {
        assert_eq!(a.ncols, b.nrows, "job {j}: inner dimensions disagree");
        let job = u32::try_from(j).expect("job count exceeds u32 tag space");
        for i in 0..a.nrows {
            let nnz = a.row_nnz(i);
            if nnz == 0 {
                continue;
            }
            let base = a.row_ptr[i];
            let nchunks = nnz.div_ceil(bundle_size);
            for ci in 0..nchunks {
                let lo = ci * bundle_size;
                let hi = ((ci + 1) * bundle_size).min(nnz);
                a_words += 2 + 2 * (hi - lo);
                chunks.push((
                    job,
                    Assignment {
                        a_row: i as Idx,
                        chunk: ci as u32,
                        last_chunk: ci + 1 == nchunks,
                        start: base + lo,
                        len: hi - lo,
                    },
                ));
            }
        }
    }
    let n_waves = chunks.len().div_ceil(pipelines);
    let prep_cpu_s = t_prep.elapsed().as_secs_f64();

    // ---- shared waves: grain-claimed (or static bands, for the
    // scaling comparison); either way the merge is wave-range order ----
    let t_waves = Instant::now();
    let nthreads = nthreads.clamp(1, n_waves.max(1));
    let chunks_ref = &chunks;
    let build = |w_lo: usize, w_hi: usize| {
        build_batch_wave_band(jobs, chunks_ref, pipelines, bundle_size, w_lo, w_hi)
    };
    let bands: Vec<(Vec<BatchWave>, Vec<f64>, usize)> = match exec {
        WaveExec::Steal(grain) => {
            let grain = grain.unwrap_or_else(|| grains::default_grain(n_waves, nthreads));
            grains::run_grains(n_waves, grain, nthreads, |_g, w_lo, w_hi| build(w_lo, w_hi))
        }
        WaveExec::StaticBands => {
            let bounds = band_bounds_by_elems(
                chunks.len(),
                |i| chunks[i].1.len,
                pipelines,
                n_waves,
                nthreads,
            );
            if bounds.len() <= 2 {
                vec![build(0, n_waves)]
            } else {
                let build = &build;
                std::thread::scope(|scope| {
                    let handles: Vec<_> = bounds
                        .windows(2)
                        .map(|w| {
                            let (lo, hi) = (w[0], w[1]);
                            scope.spawn(move || build(lo, hi))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("batch schedule worker panicked"))
                        .collect()
                })
            }
        }
    };

    // ---- deterministic merge + wall-clock normalization ----
    let mut waves = Vec::with_capacity(n_waves);
    let mut wave_cpu_s = Vec::with_capacity(n_waves);
    let mut b_words = 0usize;
    for (band_waves, band_times, band_b_words) in bands {
        waves.extend(band_waves);
        wave_cpu_s.extend(band_times);
        b_words += band_b_words;
    }
    let waves_wall_s = t_waves.elapsed().as_secs_f64();
    let raw_sum: f64 = wave_cpu_s.iter().sum();
    if raw_sum > 0.0 {
        let scale = waves_wall_s / raw_sum;
        for t in &mut wave_cpu_s {
            *t *= scale;
        }
    }

    Ok(BatchSchedule {
        pipelines,
        bundle_size,
        n_jobs: jobs.len(),
        waves,
        a_words,
        b_words,
        prep_cpu_s,
        wave_cpu_s,
    })
}

/// Build shared waves `[w_lo, w_hi)`: split each wave's chunk group into
/// per-job runs (contiguous by construction — chunks are job-major) and
/// compute each run's B-row segment as the sorted, deduped union of the
/// run's A columns against that job's B.
fn build_batch_wave_band(
    jobs: &[(Csr, Csr)],
    chunks: &[(u32, Assignment)],
    pipelines: usize,
    bundle_size: usize,
    w_lo: usize,
    w_hi: usize,
) -> (Vec<BatchWave>, Vec<f64>, usize) {
    let mut waves = Vec::with_capacity(w_hi - w_lo);
    let mut times = Vec::with_capacity(w_hi - w_lo);
    let mut b_words = 0usize;
    for wid in w_lo..w_hi {
        let t0 = Instant::now();
        let lo = wid * pipelines;
        let hi = ((wid + 1) * pipelines).min(chunks.len());
        let group = &chunks[lo..hi];
        let mut segments = Vec::new();
        let mut s = 0usize;
        while s < group.len() {
            let job = group[s].0;
            let mut e = s;
            while e < group.len() && group[e].0 == job {
                e += 1;
            }
            let (a, b) = &jobs[job as usize];
            let mut b_rows: Vec<Idx> = Vec::new();
            for (_, asg) in &group[s..e] {
                b_rows.extend_from_slice(asg.a_cols(a));
            }
            b_rows.sort_unstable();
            b_rows.dedup();
            for &r in &b_rows {
                b_words += row_stream_words(b.row_nnz(r as usize), bundle_size);
            }
            segments.push(BatchSegment { job, b_rows });
            s = e;
        }
        waves.push(BatchWave { assignments: group.to_vec(), segments });
        times.push(t0.elapsed().as_secs_f64());
    }
    (waves, times, b_words)
}

/// Compose a [`BatchSchedule`] from per-job single-job schedules by
/// packing whole single-job waves side by side into shared waves.
///
/// This is the cache-replay path of the serving runtime
/// ([`crate::serving`]): a cached [`SpgemmSchedule`] joins a shared wave
/// without re-running the CPU pass, because whole waves carry their
/// `b_rows` (and therefore their B-stream pricing) with them. Packing is
/// first-fit in job order with two ordering guarantees that keep the
/// result [`audit_batch_schedule`](crate::analysis::audit_batch_schedule)
/// clean: job *j*'s k-th wave lands at a strictly larger shared-wave
/// index than its (k−1)-th (per-job chunk order is preserved
/// wave-for-wave), and runs inside a shared wave are job-ascending
/// (jobs are packed in ascending order, so runs append in job order).
///
/// Every schedule in `singles` must match `pipelines`/`bundle_size`
/// (asserted — a cached schedule built for one design must not be
/// replayed on another). Timing fields are zeroed: the CPU cost of the
/// pass was either spent once when the single-job schedule was built, or
/// skipped entirely on a cache hit.
pub fn compose_batch(
    singles: &[SpgemmSchedule],
    pipelines: usize,
    bundle_size: usize,
) -> BatchSchedule {
    assert!(pipelines > 0 && bundle_size > 0, "zero-valued compose geometry");
    let mut waves: Vec<BatchWave> = Vec::new();
    let mut fill: Vec<usize> = Vec::new();
    let mut a_words = 0usize;
    let mut b_words = 0usize;
    for (j, s) in singles.iter().enumerate() {
        assert_eq!(s.pipelines, pipelines, "job {j}: pipeline count differs from compose target");
        assert_eq!(s.bundle_size, bundle_size, "job {j}: bundle size differs from compose target");
        let job = u32::try_from(j).expect("job count exceeds u32 tag space");
        a_words += s.a_words;
        b_words += s.b_words;
        // First shared wave this job may still use: strictly after the
        // one holding its previous wave, so wave order (= chunk order)
        // survives composition.
        let mut floor = 0usize;
        for w in &s.waves {
            let need = w.assignments.len();
            debug_assert!(need <= pipelines, "single-job wave wider than the design");
            let slot = match (floor..waves.len()).find(|&i| fill[i] + need <= pipelines) {
                Some(i) => i,
                None => {
                    waves.push(BatchWave::default());
                    fill.push(0);
                    waves.len() - 1
                }
            };
            fill[slot] += need;
            waves[slot].assignments.extend(w.assignments.iter().map(|&asg| (job, asg)));
            waves[slot].segments.push(BatchSegment { job, b_rows: w.b_rows.clone() });
            floor = slot + 1;
        }
    }
    let n_waves = waves.len();
    BatchSchedule {
        pipelines,
        bundle_size,
        n_jobs: singles.len(),
        waves,
        a_words,
        b_words,
        prep_cpu_s: 0.0,
        wave_cpu_s: vec![0.0; n_waves],
    }
}

/// Build the wave schedule for `C = A × B` with the default worker count
/// (`REAP_CPU_THREADS` or the host parallelism, capped at 16).
///
/// ```
/// use reap::rir::schedule::schedule_spgemm;
/// use reap::sparse::gen;
///
/// let a = gen::random_uniform(64, 64, 600, 1);
/// let s = schedule_spgemm(&a, &a, 8, 32);
/// // every wave holds at most `pipelines` chunks, and its B-stream is the
/// // sorted, deduped union of the wave's A columns
/// assert!(s.waves.iter().all(|w| w.assignments.len() <= 8));
/// assert!(s.waves.iter().all(|w| w.b_rows.windows(2).all(|p| p[0] < p[1])));
/// // one measured CPU cost per wave drives the overlap pipeline
/// assert_eq!(s.wave_cpu_s.len(), s.n_waves());
/// ```
pub fn schedule_spgemm(a: &Csr, b: &Csr, pipelines: usize, bundle_size: usize) -> SpgemmSchedule {
    schedule_spgemm_with_threads(a, b, pipelines, bundle_size, preprocess_threads())
}

/// Fallible form of [`schedule_spgemm`]: rejects `pipelines == 0` /
/// `bundle_size == 0` with the typed [`ConfigError`] instead of panicking.
pub fn try_schedule_spgemm(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
) -> Result<SpgemmSchedule, ConfigError> {
    try_schedule_spgemm_with_threads(a, b, pipelines, bundle_size, preprocess_threads())
}

/// Build the wave schedule for `C = A × B` on `nthreads` workers.
///
/// Rows of A are processed in order; each row is split into chunks of at
/// most `bundle_size` nonzeros; empty rows are skipped (they produce no
/// output and stream no B data). Waves are filled greedily with
/// `pipelines` chunks each. The result is identical for every
/// `nthreads` ≥ 1.
pub fn schedule_spgemm_with_threads(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> SpgemmSchedule {
    match try_schedule_spgemm_with_threads(a, b, pipelines, bundle_size, nthreads) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

/// Fallible form of [`schedule_spgemm_with_threads`] (see
/// [`try_schedule_spgemm`]).
pub fn try_schedule_spgemm_with_threads(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> Result<SpgemmSchedule, ConfigError> {
    schedule_core(a, b, pipelines, bundle_size, nthreads, WaveExec::Steal(None))
}

/// [`schedule_spgemm_with_threads`] with an explicit grain size for the
/// work-stealing executor. Output is grain-size-invariant
/// (property-tested); the knob exists for those tests and for tuning
/// experiments.
///
/// Panics on zero-valued geometry or `grain == 0`.
pub fn schedule_spgemm_with_grain(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
    grain: usize,
) -> SpgemmSchedule {
    match schedule_core(a, b, pipelines, bundle_size, nthreads, WaveExec::Steal(Some(grain))) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

/// Static-banded predecessor of [`schedule_spgemm_with_threads`], kept
/// callable for the `reap bench scaling` side-by-side: contiguous wave
/// bands balanced by A-element count, one per worker, no stealing. Output
/// is bit-identical to the work-stealing path; only the load balance
/// (and therefore the wall clock on skewed inputs) differs.
///
/// Panics on zero-valued geometry.
pub fn schedule_spgemm_static_bands(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
) -> SpgemmSchedule {
    match schedule_core(a, b, pipelines, bundle_size, nthreads, WaveExec::StaticBands) {
        Ok(s) => s,
        Err(e) => panic!("{e}"),
    }
}

fn schedule_core(
    a: &Csr,
    b: &Csr,
    pipelines: usize,
    bundle_size: usize,
    nthreads: usize,
    exec: WaveExec,
) -> Result<SpgemmSchedule, ConfigError> {
    scheduling_geometry(pipelines, bundle_size)?;
    assert_eq!(a.ncols, b.nrows, "inner dimensions disagree");

    // ---- prologue: enumerate chunks in row order (zero-copy extents) ----
    let t_prep = Instant::now();
    let total_chunks: usize = (0..a.nrows)
        .map(|i| a.row_nnz(i).div_ceil(bundle_size))
        .sum();
    let mut chunks: Vec<Assignment> = Vec::with_capacity(total_chunks);
    let mut a_words = 0usize;
    for i in 0..a.nrows {
        let nnz = a.row_nnz(i);
        if nnz == 0 {
            continue;
        }
        let base = a.row_ptr[i];
        let nchunks = nnz.div_ceil(bundle_size);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(nnz);
            a_words += 2 + 2 * (hi - lo);
            chunks.push(Assignment {
                a_row: i as Idx,
                chunk: ci as u32,
                last_chunk: ci + 1 == nchunks,
                start: base + lo,
                len: hi - lo,
            });
        }
    }
    let n_waves = chunks.len().div_ceil(pipelines);
    let prep_cpu_s = t_prep.elapsed().as_secs_f64();

    // ---- wave building: grain-claimed with stealing (or static bands,
    // for the scaling comparison); merged in wave-range order ----
    let t_waves = Instant::now();
    let nthreads = nthreads.clamp(1, n_waves.max(1));
    let chunks_ref = &chunks;
    let build = |scratch: &mut WaveScratch, w_lo: usize, w_hi: usize| {
        build_wave_band(a, b, chunks_ref, pipelines, bundle_size, w_lo, w_hi, scratch)
    };
    let bands: Vec<(Vec<Wave>, Vec<f64>, usize)> = match exec {
        WaveExec::Steal(grain) => {
            let grain = grain.unwrap_or_else(|| grains::default_grain(n_waves, nthreads));
            grains::run_grains_with(
                n_waves,
                grain,
                nthreads,
                || WaveScratch::new(b.nrows),
                |scratch, _g, w_lo, w_hi| build(scratch, w_lo, w_hi),
            )
        }
        WaveExec::StaticBands => {
            let bounds = wave_band_bounds(&chunks, pipelines, n_waves, nthreads);
            if bounds.len() <= 2 {
                vec![build(&mut WaveScratch::new(b.nrows), 0, n_waves)]
            } else {
                let build = &build;
                std::thread::scope(|scope| {
                    let handles: Vec<_> = bounds
                        .windows(2)
                        .map(|w| {
                            let (lo, hi) = (w[0], w[1]);
                            scope.spawn(move || build(&mut WaveScratch::new(b.nrows), lo, hi))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("schedule worker panicked"))
                        .collect()
                })
            }
        }
    };

    // ---- deterministic merge: bands are contiguous wave ranges ----
    let mut waves = Vec::with_capacity(n_waves);
    let mut wave_cpu_s = Vec::with_capacity(n_waves);
    let mut b_words = 0usize;
    for (band_waves, band_times, band_b_words) in bands {
        waves.extend(band_waves);
        wave_cpu_s.extend(band_times);
        b_words += band_b_words;
    }
    // normalize per-wave durations to the phase's wall clock: under the
    // pool the raw durations overlap in time, but the overlap model wants
    // costs whose sum is what the CPU actually spent end-to-end
    let waves_wall_s = t_waves.elapsed().as_secs_f64();
    let raw_sum: f64 = wave_cpu_s.iter().sum();
    if raw_sum > 0.0 {
        let scale = waves_wall_s / raw_sum;
        for t in &mut wave_cpu_s {
            *t *= scale;
        }
    }

    Ok(SpgemmSchedule {
        pipelines,
        bundle_size,
        waves,
        a_words,
        b_words,
        prep_cpu_s,
        wave_cpu_s,
    })
}

/// Split `0..n_waves` into ≤ `nthreads` contiguous ranges with roughly
/// equal A-element totals (wave cost is dominated by the union over the
/// wave's elements). Returns ascending boundaries, first 0, last `n_waves`.
fn wave_band_bounds(
    chunks: &[Assignment],
    pipelines: usize,
    n_waves: usize,
    nthreads: usize,
) -> Vec<usize> {
    band_bounds_by_elems(chunks.len(), |i| chunks[i].len, pipelines, n_waves, nthreads)
}

/// Core of [`wave_band_bounds`], shared with the batch scheduler: balance
/// contiguous wave ranges by per-chunk element counts. Takes a length
/// accessor instead of a materialized slice so neither caller allocates.
fn band_bounds_by_elems(
    n_chunks: usize,
    chunk_len: impl Fn(usize) -> usize,
    pipelines: usize,
    n_waves: usize,
    nthreads: usize,
) -> Vec<usize> {
    if n_waves == 0 || nthreads <= 1 {
        return vec![0, n_waves];
    }
    // element count per wave (wave wid covers chunks[wid*p .. (wid+1)*p))
    let wave_elems = |wid: usize| -> usize {
        let lo = wid * pipelines;
        let hi = ((wid + 1) * pipelines).min(n_chunks);
        (lo..hi).map(&chunk_len).sum()
    };
    let total: usize = (0..n_chunks).map(&chunk_len).sum();
    let mut bounds = vec![0usize];
    let mut wid = 0usize;
    let mut before = 0usize; // elements in waves < wid
    for k in 1..nthreads {
        let target = total * k / nthreads;
        while wid < n_waves && before < target {
            before += wave_elems(wid);
            wid += 1;
        }
        if wid > *bounds.last().unwrap() && wid < n_waves {
            bounds.push(wid);
        }
    }
    bounds.push(n_waves);
    bounds
}

/// Per-worker scratch for [`build_wave_band`]: the wave-stamped `mark`
/// array plus a high-water B-row capacity hint. Reusing one scratch
/// across *every* wave a worker claims — including stolen, out-of-order
/// waves — is safe because each wave is processed exactly once globally
/// and stamps with its globally unique wave id.
struct WaveScratch {
    /// Wave id when a B-row was last added (dedup stamp).
    mark: Vec<u32>,
    b_rows_cap: usize,
}

impl WaveScratch {
    fn new(b_nrows: usize) -> Self {
        WaveScratch { mark: vec![u32::MAX; b_nrows], b_rows_cap: 0 }
    }
}

/// Build waves `[w_lo, w_hi)` reusing the worker's scratch; returns the
/// waves, their raw per-wave durations, and the range's B-word total.
fn build_wave_band(
    a: &Csr,
    b: &Csr,
    chunks: &[Assignment],
    pipelines: usize,
    bundle_size: usize,
    w_lo: usize,
    w_hi: usize,
    scratch: &mut WaveScratch,
) -> (Vec<Wave>, Vec<f64>, usize) {
    let mut waves = Vec::with_capacity(w_hi - w_lo);
    let mut times = Vec::with_capacity(w_hi - w_lo);
    let mut b_words = 0usize;
    let mark = &mut scratch.mark;
    for wid in w_lo..w_hi {
        let t0 = Instant::now();
        // checked: a wave count past u32::MAX would silently alias marks
        let wid32 = u32::try_from(wid).expect("wave count exceeds u32 mark space");
        let lo = wid * pipelines;
        let hi = ((wid + 1) * pipelines).min(chunks.len());
        let group = &chunks[lo..hi];
        let mut b_rows: Vec<Idx> = Vec::with_capacity(scratch.b_rows_cap);
        for asg in group {
            for &c in asg.a_cols(a) {
                let r = c as usize;
                if mark[r] != wid32 {
                    mark[r] = wid32;
                    b_rows.push(c);
                }
            }
        }
        b_rows.sort_unstable();
        for &r in &b_rows {
            b_words += row_stream_words(b.row_nnz(r as usize), bundle_size);
        }
        scratch.b_rows_cap = scratch.b_rows_cap.max(b_rows.len());
        waves.push(Wave { assignments: group.to_vec(), b_rows });
        times.push(t0.elapsed().as_secs_f64());
    }
    (waves, times, b_words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn mk(n: usize, nnz: usize, seed: u64) -> Csr {
        gen::random_uniform(n, n, nnz, seed)
    }

    #[test]
    fn every_chunk_scheduled_exactly_once() {
        let a = mk(50, 600, 1);
        let b = mk(50, 600, 2);
        let s = schedule_spgemm(&a, &b, 8, 32);
        let mut seen = std::collections::HashSet::new();
        let mut per_row_elems = vec![0usize; a.nrows];
        for w in &s.waves {
            assert!(w.assignments.len() <= 8);
            for asg in &w.assignments {
                assert!(seen.insert((asg.a_row, asg.chunk)), "duplicate chunk");
                assert!(asg.len <= 32 && asg.len > 0);
                assert_eq!(asg.a_cols(&a).len(), asg.len);
                per_row_elems[asg.a_row as usize] += asg.len;
            }
        }
        for i in 0..a.nrows {
            assert_eq!(per_row_elems[i], a.row_nnz(i), "row {i} element coverage");
        }
    }

    #[test]
    fn wave_b_rows_is_union_of_wave_a_cols() {
        let a = mk(40, 300, 3);
        let b = mk(40, 300, 4);
        let s = schedule_spgemm(&a, &b, 4, 16);
        for w in &s.waves {
            let mut expect: Vec<Idx> = w
                .assignments
                .iter()
                .flat_map(|asg| asg.a_cols(&a).iter().copied())
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(w.b_rows, expect);
        }
    }

    #[test]
    fn big_rows_split_and_marked() {
        let a = gen::random_uniform(1, 200, 100, 5); // one row of 100 nnz
        let b = mk(200, 400, 6);
        let s = schedule_spgemm(&a, &b, 4, 32);
        let chunks: Vec<&Assignment> =
            s.waves.iter().flat_map(|w| w.assignments.iter()).collect();
        assert_eq!(chunks.len(), 4); // 32+32+32+4
        assert!(chunks[..3].iter().all(|c| !c.last_chunk));
        assert!(chunks[3].last_chunk);
        assert_eq!(chunks[3].len, 4);
    }

    #[test]
    fn empty_rows_skipped() {
        let mut a = Csr::new(5, 5);
        a.row_ptr = vec![0, 0, 0, 0, 0, 0];
        let b = mk(5, 10, 7);
        let s = schedule_spgemm(&a, &b, 2, 32);
        assert_eq!(s.n_waves(), 0);
        assert_eq!(s.input_bytes(), 0);
        assert!(s.wave_cpu_s.is_empty());
        assert_eq!(s.cpu_total_s(), s.prep_cpu_s);
    }

    #[test]
    fn traffic_accounting_positive_and_scales_with_pipelines() {
        let a = mk(60, 900, 8);
        let b = mk(60, 900, 9);
        let s1 = schedule_spgemm(&a, &b, 1, 32);
        let s16 = schedule_spgemm(&a, &b, 16, 32);
        assert!(s1.input_bytes() > 0);
        // wider waves share B-streams: fewer waves, less (or equal) B traffic
        assert!(s16.b_words <= s1.b_words);
        assert_eq!(s16.a_words, s1.a_words); // A streamed once either way
    }

    #[test]
    fn row_stream_words_formula() {
        assert_eq!(row_stream_words(0, 32), 2); // empty row: header-only bundle
        assert_eq!(row_stream_words(32, 32), 2 + 64);
        assert_eq!(row_stream_words(33, 32), 4 + 66); // two chunks
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let a = gen::power_law(120, 2600, 10);
        let b = mk(120, 1800, 11);
        let base = schedule_spgemm_with_threads(&a, &b, 8, 16, 1);
        for t in [2usize, 3, 4, 8, 64] {
            let par = schedule_spgemm_with_threads(&a, &b, 8, 16, t);
            assert_eq!(par.waves, base.waves, "threads={t}");
            assert_eq!(par.a_words, base.a_words, "threads={t}");
            assert_eq!(par.b_words, base.b_words, "threads={t}");
        }
    }

    #[test]
    fn zero_geometry_rejected_with_typed_error() {
        let a = mk(8, 30, 1);
        let b = mk(8, 30, 2);
        assert_eq!(try_schedule_spgemm(&a, &b, 0, 32).unwrap_err(), ConfigError::ZeroPipelines);
        assert_eq!(try_schedule_spgemm(&a, &b, 8, 0).unwrap_err(), ConfigError::ZeroBundleSize);
        let jobs = vec![(mk(8, 30, 3), mk(8, 30, 4))];
        assert_eq!(
            try_schedule_spgemm_batch(&jobs, 0, 32).unwrap_err(),
            ConfigError::ZeroPipelines
        );
        assert_eq!(
            try_schedule_spgemm_batch(&jobs, 8, 0).unwrap_err(),
            ConfigError::ZeroBundleSize
        );
    }

    #[test]
    #[should_panic(expected = "bundle_size must be >= 1")]
    fn infallible_schedule_panics_with_the_config_message() {
        let a = mk(8, 30, 1);
        let _ = schedule_spgemm(&a, &a, 8, 0);
    }

    #[test]
    #[should_panic(expected = "pipelines must be >= 1")]
    fn infallible_batch_schedule_panics_with_the_config_message() {
        let jobs = vec![(mk(8, 30, 3), mk(8, 30, 4))];
        let _ = schedule_spgemm_batch(&jobs, 0, 32);
    }

    #[test]
    fn wave_timestamps_cover_every_wave() {
        let a = mk(80, 1200, 12);
        let b = mk(80, 1200, 13);
        for t in [1usize, 4] {
            let s = schedule_spgemm_with_threads(&a, &b, 4, 16, t);
            assert_eq!(s.wave_cpu_s.len(), s.n_waves());
            assert!(s.wave_cpu_s.iter().all(|&x| x >= 0.0));
            assert!(s.prep_cpu_s >= 0.0);
            let sum: f64 = s.wave_cpu_s.iter().sum();
            assert!((s.cpu_total_s() - s.prep_cpu_s - sum).abs() < 1e-15);
        }
    }

    // ---- batch (multi-tenant) scheduling ----

    fn mk_jobs(n_jobs: usize, n: usize, nnz: usize, seed: u64) -> Vec<(Csr, Csr)> {
        (0..n_jobs)
            .map(|j| {
                let s = seed + j as u64 * 10;
                (mk(n, nnz, s), mk(n, nnz, s + 1))
            })
            .collect()
    }

    #[test]
    fn batch_packs_small_jobs_into_full_waves() {
        // 6 jobs × ~40 chunks on 64 pipelines: alone each job underfills
        // its wave; batched, all waves but the last are full
        let jobs = mk_jobs(6, 40, 200, 20);
        let s = schedule_spgemm_batch(&jobs, 64, 32);
        assert_eq!(s.n_jobs, 6);
        for (i, w) in s.waves.iter().enumerate() {
            assert!(w.assignments.len() <= 64);
            if i + 1 < s.n_waves() {
                assert_eq!(w.assignments.len(), 64, "interior wave {i} must be full");
            }
            // segments mirror the job runs exactly
            let mut run_jobs: Vec<u32> = w.assignments.iter().map(|&(j, _)| j).collect();
            run_jobs.dedup();
            let seg_jobs: Vec<u32> = w.segments.iter().map(|seg| seg.job).collect();
            assert_eq!(seg_jobs, run_jobs, "wave {i} segment order");
        }
        let solo_occ: f64 = {
            let one = schedule_spgemm(&jobs[0].0, &jobs[0].1, 64, 32);
            one.n_chunks() as f64 / (one.n_waves() * 64) as f64
        };
        assert!(s.slot_occupancy() > solo_occ, "batching must pack tighter");
    }

    #[test]
    fn batch_segments_are_per_job_unions() {
        let jobs = mk_jobs(3, 30, 150, 40);
        let s = schedule_spgemm_batch(&jobs, 8, 16);
        for w in &s.waves {
            for seg in &w.segments {
                let a = &jobs[seg.job as usize].0;
                let mut expect: Vec<Idx> = w
                    .assignments
                    .iter()
                    .filter(|&&(j, _)| j == seg.job)
                    .flat_map(|(_, asg)| asg.a_cols(a).iter().copied())
                    .collect();
                expect.sort_unstable();
                expect.dedup();
                assert_eq!(seg.b_rows, expect);
            }
        }
    }

    #[test]
    fn batch_decomposes_into_single_job_schedules() {
        let mut jobs = mk_jobs(4, 35, 180, 60);
        jobs.push((Csr::new(5, 7), Csr::new(7, 3))); // empty job
        for pipelines in [4usize, 32, 128] {
            let batch = schedule_spgemm_batch(&jobs, pipelines, 16);
            let singles = batch.decompose(&jobs);
            assert_eq!(singles.len(), jobs.len());
            let mut a_words = 0usize;
            for (j, (a, b)) in jobs.iter().enumerate() {
                let solo = schedule_spgemm(a, b, pipelines, 16);
                assert_eq!(singles[j].waves, solo.waves, "job {j} p {pipelines}");
                assert_eq!(singles[j].a_words, solo.a_words, "job {j}");
                assert_eq!(singles[j].b_words, solo.b_words, "job {j}");
                a_words += solo.a_words;
            }
            assert_eq!(batch.a_words, a_words, "A traffic sums over jobs");
        }
    }

    #[test]
    fn batch_parallel_matches_serial_bitwise() {
        let jobs = mk_jobs(5, 45, 400, 80);
        let base = schedule_spgemm_batch_with_threads(&jobs, 8, 16, 1);
        for t in [2usize, 3, 4, 8] {
            let par = schedule_spgemm_batch_with_threads(&jobs, 8, 16, t);
            assert_eq!(par.waves, base.waves, "threads={t}");
            assert_eq!(par.a_words, base.a_words, "threads={t}");
            assert_eq!(par.b_words, base.b_words, "threads={t}");
            assert_eq!(par.wave_cpu_s.len(), par.n_waves());
        }
    }

    #[test]
    fn batch_of_empty_jobs_is_empty() {
        let jobs = vec![(Csr::new(4, 4), Csr::new(4, 4)), (Csr::new(0, 3), Csr::new(3, 2))];
        let s = schedule_spgemm_batch(&jobs, 8, 32);
        assert_eq!(s.n_waves(), 0);
        assert_eq!(s.input_bytes(), 0);
        assert_eq!(s.slot_occupancy(), 0.0);
        assert!(s.decompose(&jobs).iter().all(|sch| sch.waves.is_empty()));
    }

    #[test]
    fn composed_batch_is_audit_clean_and_decomposes_to_its_inputs() {
        let jobs = mk_jobs(5, 35, 180, 90);
        for pipelines in [8usize, 64] {
            let singles: Vec<SpgemmSchedule> =
                jobs.iter().map(|(a, b)| schedule_spgemm(a, b, pipelines, 16)).collect();
            let batch = compose_batch(&singles, pipelines, 16);
            assert_eq!(batch.n_jobs, jobs.len());
            let diags = crate::analysis::audit_batch_schedule(&jobs, &batch);
            assert!(diags.is_empty(), "composed schedule must audit clean: {diags:?}");
            for (j, (single, back)) in singles.iter().zip(batch.decompose(&jobs)).enumerate() {
                assert_eq!(back.waves, single.waves, "job {j} p {pipelines}");
            }
            let a_words: usize = singles.iter().map(|s| s.a_words).sum();
            let b_words: usize = singles.iter().map(|s| s.b_words).sum();
            assert_eq!(batch.a_words, a_words);
            assert_eq!(batch.b_words, b_words);
            assert_eq!(batch.wave_cpu_s.len(), batch.n_waves());
        }
    }

    #[test]
    fn compose_respects_capacity_and_per_job_wave_order() {
        let jobs = mk_jobs(7, 40, 220, 110);
        let singles: Vec<SpgemmSchedule> =
            jobs.iter().map(|(a, b)| schedule_spgemm(a, b, 16, 16)).collect();
        let batch = compose_batch(&singles, 16, 16);
        let mut last_wave: Vec<Option<usize>> = vec![None; jobs.len()];
        for (wid, w) in batch.waves.iter().enumerate() {
            assert!(w.assignments.len() <= 16, "wave {wid} overfull");
            let mut run_jobs: Vec<u32> = w.assignments.iter().map(|&(j, _)| j).collect();
            run_jobs.dedup();
            assert!(run_jobs.windows(2).all(|p| p[0] < p[1]), "wave {wid} run order");
            for &j in &run_jobs {
                let j = j as usize;
                assert!(last_wave[j].map_or(true, |prev| prev < wid), "job {j} wave order");
                last_wave[j] = Some(wid);
            }
        }
        // every single-job wave landed somewhere
        let packed: usize = batch.waves.iter().map(|w| w.segments.len()).sum();
        let expect: usize = singles.iter().map(SpgemmSchedule::n_waves).sum();
        assert_eq!(packed, expect);
    }

    #[test]
    fn compose_of_no_jobs_is_empty() {
        let batch = compose_batch(&[], 8, 32);
        assert_eq!(batch.n_waves(), 0);
        assert_eq!(batch.n_jobs, 0);
        assert_eq!(batch.input_bytes(), 0);
    }

    #[test]
    fn band_bounds_partition_waves() {
        let a = mk(200, 4000, 14);
        let b = mk(200, 4000, 15);
        let s = schedule_spgemm_with_threads(&a, &b, 4, 8, 1);
        let chunks: Vec<Assignment> =
            s.waves.iter().flat_map(|w| w.assignments.iter().copied()).collect();
        let bounds = wave_band_bounds(&chunks, 4, s.n_waves(), 5);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), s.n_waves());
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        assert!(bounds.len() <= 6);
    }

    // ---- pinned static-banding edge cases (the behavior the stealing
    // executor replaced; kept so the two paths stay diffable) ----

    #[test]
    fn band_bounds_more_threads_than_waves() {
        // 4 waves of one chunk each, 9 requested threads: boundaries must
        // still strictly ascend and partition 0..4 — at most 4 bands; the
        // surplus threads simply get no band
        let lens = [3usize, 5, 2, 7];
        let bounds = band_bounds_by_elems(4, |i| lens[i], 1, 4, 9);
        assert_eq!(bounds, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn band_bounds_empty_schedule() {
        // no waves: the degenerate [0, 0] partition, same as 1 thread
        assert_eq!(band_bounds_by_elems(0, |_| 0, 4, 0, 8), vec![0, 0]);
        assert_eq!(band_bounds_by_elems(7, |_| 3, 4, 2, 1), vec![0, 2]);
    }

    #[test]
    fn band_bounds_single_giant_wave_starves_bands() {
        // one giant wave among tiny ones: the prefix walk hands the giant
        // to band 0 and collapses the rest into one band — 2 bands for 4
        // threads. This is the skew pathology that motivates stealing
        // (grains keep all workers claimable until the pool drains).
        let lens = [100usize, 1, 1, 1];
        let bounds = band_bounds_by_elems(4, |i| lens[i], 1, 4, 4);
        assert_eq!(bounds, vec![0, 1, 4]);
        // a single wave is atomic: nothing to split regardless of threads
        let bounds = band_bounds_by_elems(4, |i| lens[i], 4, 1, 8);
        assert_eq!(bounds, vec![0, 1]);
    }

    // ---- work-stealing vs static banding vs grain size ----

    #[test]
    fn static_bands_match_stealing_bitwise() {
        let a = gen::power_law(100, 2200, 21);
        let b = mk(100, 1500, 22);
        let steal = schedule_spgemm_with_threads(&a, &b, 8, 16, 4);
        for t in [1usize, 2, 4, 8] {
            let stat = schedule_spgemm_static_bands(&a, &b, 8, 16, t);
            assert_eq!(stat.waves, steal.waves, "threads={t}");
            assert_eq!(stat.a_words, steal.a_words, "threads={t}");
            assert_eq!(stat.b_words, steal.b_words, "threads={t}");
        }
        let jobs = mk_jobs(4, 40, 300, 23);
        let steal_b = schedule_spgemm_batch_with_threads(&jobs, 8, 16, 4);
        for t in [1usize, 2, 4, 8] {
            let stat_b = schedule_spgemm_batch_static_bands(&jobs, 8, 16, t);
            assert_eq!(stat_b.waves, steal_b.waves, "threads={t}");
            assert_eq!(stat_b.b_words, steal_b.b_words, "threads={t}");
        }
    }

    #[test]
    fn grain_size_never_changes_the_schedule() {
        let a = gen::power_law(90, 2000, 24);
        let b = mk(90, 1400, 25);
        let base = schedule_spgemm_with_threads(&a, &b, 4, 16, 1);
        let jobs = mk_jobs(3, 35, 250, 26);
        let base_b = schedule_spgemm_batch_with_threads(&jobs, 4, 16, 1);
        for grain in [1usize, 4, 1 << 20] {
            for t in [2usize, 4, 8] {
                let s = schedule_spgemm_with_grain(&a, &b, 4, 16, t, grain);
                assert_eq!(s.waves, base.waves, "grain={grain} t={t}");
                assert_eq!(s.b_words, base.b_words, "grain={grain} t={t}");
                let sb = schedule_spgemm_batch_with_grain(&jobs, 4, 16, t, grain);
                assert_eq!(sb.waves, base_b.waves, "grain={grain} t={t}");
                assert_eq!(sb.b_words, base_b.b_words, "grain={grain} t={t}");
            }
        }
    }
}
