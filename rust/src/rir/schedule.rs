//! The CPU's scheduling pass for SpGEMM (paper Fig 3).
//!
//! "CPU is aware of the number of parallel pipelines in the FPGA to
//! properly perform the scheduling task. Each pipeline processes a row of
//! A. Hence, it has laid out the rows of A followed by all the rows of B
//! necessary to produce all partial products."
//!
//! The schedule groups A-row *chunks* (≤ bundle size, big rows split per
//! §III-A) into **waves** of at most `pipelines` chunks. For each wave the
//! CPU computes the set of B-rows that must be streamed — the union of the
//! column indices of the wave's A elements, deduplicated and sorted so the
//! FPGA sees a monotone DRAM address pattern.

use crate::sparse::{Csr, Idx, Val};

use super::layout::WORD_BYTES;

/// One pipeline's work for a wave: a chunk of a row of A (loaded into the
/// pipeline's CAM as `column index → value`).
///
/// Zero-copy: the chunk is identified by its extent in the source CSR's
/// element arrays (cloning per-chunk vectors made preprocessing dominate
/// end-to-end time on low-degree matrices — see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    /// Source row of A.
    pub a_row: Idx,
    /// Chunk ordinal within the row (0-based).
    pub chunk: u32,
    /// True for the last chunk of its row — the pipeline emits the merged
    /// row segment downstream when this chunk completes.
    pub last_chunk: bool,
    /// Start offset of the chunk in the CSR `cols`/`vals` arrays.
    pub start: usize,
    /// Chunk length (≤ bundle size).
    pub len: usize,
}

impl Assignment {
    /// Column indices of the chunk (the CAM keys).
    #[inline]
    pub fn a_cols<'a>(&self, a: &'a Csr) -> &'a [Idx] {
        &a.cols[self.start..self.start + self.len]
    }

    /// Values of the chunk.
    #[inline]
    pub fn a_vals<'a>(&self, a: &'a Csr) -> &'a [Val] {
        &a.vals[self.start..self.start + self.len]
    }
}

/// One scheduling wave: ≤ `pipelines` assignments plus the B-row stream
/// they share.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Wave {
    pub assignments: Vec<Assignment>,
    /// B-rows broadcast to all pipelines this wave (ascending, deduped).
    pub b_rows: Vec<Idx>,
}

/// The complete SpGEMM schedule plus DRAM traffic accounting.
#[derive(Clone, Debug)]
pub struct SpgemmSchedule {
    pub pipelines: usize,
    pub bundle_size: usize,
    pub waves: Vec<Wave>,
    /// Words of A-side bundles streamed (each chunk: 2 header + 2/elem).
    pub a_words: usize,
    /// Words of B-side bundles streamed, summed over waves (B rows are
    /// re-streamed per wave that needs them — the row-by-row formulation's
    /// cost, paper §III-A "the B-matrix is streamed into the FPGA for each
    /// row of A").
    pub b_words: usize,
}

impl SpgemmSchedule {
    /// Bytes of input streamed into the FPGA.
    pub fn input_bytes(&self) -> usize {
        (self.a_words + self.b_words) * WORD_BYTES
    }

    /// Number of waves.
    pub fn n_waves(&self) -> usize {
        self.waves.len()
    }

    /// Total A chunks scheduled.
    pub fn n_chunks(&self) -> usize {
        self.waves.iter().map(|w| w.assignments.len()).sum()
    }
}

/// Words to stream one bundle-chain of a row with `nnz` elements.
fn row_stream_words(nnz: usize, bundle_size: usize) -> usize {
    let chunks = nnz.div_ceil(bundle_size).max(1);
    2 * chunks + 2 * nnz
}

/// Build the wave schedule for `C = A × B`.
///
/// Rows of A are processed in order; each row is split into chunks of at
/// most `bundle_size` nonzeros; empty rows are skipped (they produce no
/// output and stream no B data). Waves are filled greedily with
/// `pipelines` chunks each.
pub fn schedule_spgemm(a: &Csr, b: &Csr, pipelines: usize, bundle_size: usize) -> SpgemmSchedule {
    assert!(pipelines > 0 && bundle_size > 0);
    assert_eq!(a.ncols, b.nrows, "inner dimensions disagree");

    // Enumerate chunks in row order (zero-copy extents into `a`).
    let total_chunks: usize = (0..a.nrows)
        .map(|i| a.row_nnz(i).div_ceil(bundle_size))
        .sum();
    let mut chunks: Vec<Assignment> = Vec::with_capacity(total_chunks);
    for i in 0..a.nrows {
        let nnz = a.row_nnz(i);
        if nnz == 0 {
            continue;
        }
        let base = a.row_ptr[i];
        let nchunks = nnz.div_ceil(bundle_size);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(nnz);
            chunks.push(Assignment {
                a_row: i as Idx,
                chunk: ci as u32,
                last_chunk: ci + 1 == nchunks,
                start: base + lo,
                len: hi - lo,
            });
        }
    }

    let mut waves = Vec::with_capacity(chunks.len().div_ceil(pipelines));
    let mut a_words = 0usize;
    let mut b_words = 0usize;
    let mut mark = vec![u32::MAX; b.nrows]; // wave id when row last added
    let mut b_rows_cap = 0usize;
    for (wid, group) in chunks.chunks(pipelines).enumerate() {
        let mut b_rows: Vec<Idx> = Vec::with_capacity(b_rows_cap);
        for asg in group {
            a_words += 2 + 2 * asg.len;
            for &c in asg.a_cols(a) {
                let r = c as usize;
                if mark[r] != wid as u32 {
                    mark[r] = wid as u32;
                    b_rows.push(c);
                }
            }
        }
        b_rows.sort_unstable();
        for &r in &b_rows {
            b_words += row_stream_words(b.row_nnz(r as usize), bundle_size);
        }
        b_rows_cap = b_rows_cap.max(b_rows.len());
        waves.push(Wave { assignments: group.to_vec(), b_rows });
    }

    SpgemmSchedule { pipelines, bundle_size, waves, a_words, b_words }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn mk(n: usize, nnz: usize, seed: u64) -> Csr {
        gen::random_uniform(n, n, nnz, seed)
    }

    #[test]
    fn every_chunk_scheduled_exactly_once() {
        let a = mk(50, 600, 1);
        let b = mk(50, 600, 2);
        let s = schedule_spgemm(&a, &b, 8, 32);
        let mut seen = std::collections::HashSet::new();
        let mut per_row_elems = vec![0usize; a.nrows];
        for w in &s.waves {
            assert!(w.assignments.len() <= 8);
            for asg in &w.assignments {
                assert!(seen.insert((asg.a_row, asg.chunk)), "duplicate chunk");
                assert!(asg.len <= 32 && asg.len > 0);
                assert_eq!(asg.a_cols(&a).len(), asg.len);
                per_row_elems[asg.a_row as usize] += asg.len;
            }
        }
        for i in 0..a.nrows {
            assert_eq!(per_row_elems[i], a.row_nnz(i), "row {i} element coverage");
        }
    }

    #[test]
    fn wave_b_rows_is_union_of_wave_a_cols() {
        let a = mk(40, 300, 3);
        let b = mk(40, 300, 4);
        let s = schedule_spgemm(&a, &b, 4, 16);
        for w in &s.waves {
            let mut expect: Vec<Idx> = w
                .assignments
                .iter()
                .flat_map(|asg| asg.a_cols(&a).iter().copied())
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(w.b_rows, expect);
        }
    }

    #[test]
    fn big_rows_split_and_marked() {
        let a = gen::random_uniform(1, 200, 100, 5); // one row of 100 nnz
        let b = mk(200, 400, 6);
        let s = schedule_spgemm(&a, &b, 4, 32);
        let chunks: Vec<&Assignment> =
            s.waves.iter().flat_map(|w| w.assignments.iter()).collect();
        assert_eq!(chunks.len(), 4); // 32+32+32+4
        assert!(chunks[..3].iter().all(|c| !c.last_chunk));
        assert!(chunks[3].last_chunk);
        assert_eq!(chunks[3].len, 4);
    }

    #[test]
    fn empty_rows_skipped() {
        let mut a = Csr::new(5, 5);
        a.row_ptr = vec![0, 0, 0, 0, 0, 0];
        let b = mk(5, 10, 7);
        let s = schedule_spgemm(&a, &b, 2, 32);
        assert_eq!(s.n_waves(), 0);
        assert_eq!(s.input_bytes(), 0);
    }

    #[test]
    fn traffic_accounting_positive_and_scales_with_pipelines() {
        let a = mk(60, 900, 8);
        let b = mk(60, 900, 9);
        let s1 = schedule_spgemm(&a, &b, 1, 32);
        let s16 = schedule_spgemm(&a, &b, 16, 32);
        assert!(s1.input_bytes() > 0);
        // wider waves share B-streams: fewer waves, less (or equal) B traffic
        assert!(s16.b_words <= s1.b_words);
        assert_eq!(s16.a_words, s1.a_words); // A streamed once either way
    }

    #[test]
    fn row_stream_words_formula() {
        assert_eq!(row_stream_words(0, 32), 2); // empty row: header-only bundle
        assert_eq!(row_stream_words(32, 32), 2 + 64);
        assert_eq!(row_stream_words(33, 32), 4 + 66); // two chunks
    }
}
