//! The paper's `decompress` routine: RIR bundles → CSR.
//!
//! "To support any sparse format, one has to provide compress and
//! decompress routines" (§II). Decoding validates the stream invariants the
//! FPGA input controller relies on: bundles of one row are contiguous, each
//! row chain ends with exactly one `END_OF_ROW`, metadata-only bundles
//! carry no matrix data. Dense-panel bundles (the SpMM right-hand-side
//! block, [`BundleStream::encode_csr_with_panel`]) are skipped by the
//! sparse assemblers — they route to the on-chip panel RAM, not the CAMs —
//! and reassembled by [`stream_panel_to_dense`].
//!
//! Two API tiers exist for each of the three decoders:
//!
//! * `try_*` — fallible, total over arbitrary input, returning the typed
//!   [`RirError`]. The `try_words_*` forms additionally take the raw
//!   serialized word stream (the untrusted wire bytes) and verify
//!   per-bundle CRC32 checksums as they walk — this is the path faulty
//!   DRAM/PCIe transfers go through, and the one the fuzz targets drive.
//! * the legacy infallible-looking entry points (`anyhow` errors) — thin
//!   wrappers over the `try_*` forms for trusted in-process streams.

use anyhow::{bail, Result};

use crate::sparse::{Csr, Idx, Val};

use super::bundle::{Bundle, BundleFlags, Payload};
use super::encode::BundleStream;
use super::error::RirError;
use super::layout;

/// Reassemble a CSR matrix from a bundle stream produced by
/// [`super::encode::csr_to_bundles`].
///
/// `nrows`/`ncols` give the target shape (the stream itself is
/// shape-agnostic, exactly like the hardware). Metadata-only bundles are
/// skipped (they carry scheduling, not data).
pub fn bundles_to_csr(bundles: &[Bundle], nrows: usize, ncols: usize) -> Result<Csr> {
    let mut asm = RowAssembler::new(nrows, ncols);
    for b in bundles {
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        let (distinct, values) = match &b.payload {
            Payload::Data { distinct, values } => (distinct, values),
            Payload::Schedule { .. } => {
                bail!("schedule payload without METADATA_ONLY flag")
            }
        };
        asm.push(b.shared, b.flags, distinct, values)?;
    }
    Ok(asm.finish()?)
}

/// Reassemble a CSR matrix from a flat [`BundleStream`] arena — identical
/// validation to [`bundles_to_csr`] without materializing boxed bundles.
/// Trusted-caller wrapper over [`try_stream_to_csr`].
pub fn stream_to_csr(stream: &BundleStream, nrows: usize, ncols: usize) -> Result<Csr> {
    Ok(try_stream_to_csr(stream, nrows, ncols)?)
}

/// Fallible form of [`stream_to_csr`]: malformed streams come back as a
/// structured [`RirError`], never a panic.
pub fn try_stream_to_csr(
    stream: &BundleStream,
    nrows: usize,
    ncols: usize,
) -> std::result::Result<Csr, RirError> {
    let mut asm = RowAssembler::new(nrows, ncols);
    for b in stream.iter() {
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.push(b.shared, b.flags, b.cols, b.vals)?;
    }
    asm.finish()
}

/// Reassemble one tenant's CSR from its bundle segment `[lo, hi)` of a
/// shared multi-job stream (the boundaries returned by
/// [`BundleStream::encode_csr_jobs`]). Validation is identical to
/// [`stream_to_csr`] — the segment must be a self-contained stream.
/// Trusted-caller wrapper over [`try_stream_segment_to_csr`].
pub fn stream_segment_to_csr(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    ncols: usize,
) -> Result<Csr> {
    Ok(try_stream_segment_to_csr(stream, lo, hi, nrows, ncols)?)
}

/// Fallible form of [`stream_segment_to_csr`].
pub fn try_stream_segment_to_csr(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    ncols: usize,
) -> std::result::Result<Csr, RirError> {
    if lo > hi || hi > stream.n_bundles() {
        return Err(RirError::SegmentOutOfBounds { lo, hi, n_bundles: stream.n_bundles() });
    }
    let mut asm = RowAssembler::new(nrows, ncols);
    for i in lo..hi {
        let b = stream.bundle(i);
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.push(b.shared, b.flags, b.cols, b.vals)?;
    }
    asm.finish()
}

/// Reassemble the dense right-hand-side panel X from its bundle segment
/// `[lo, hi)` of a combined SpMM stream (the boundary returned by
/// [`BundleStream::encode_csr_with_panel`]).
///
/// `nrows` is the panel's row count (= the sparse matrix's column count)
/// and `k` its lane width; the result is row-major `nrows × k`, exactly
/// the layout [`crate::kernels::spmm::spmm`] consumes. Validation mirrors
/// the sparse assembler's: every bundle in the segment must carry the
/// `DENSE_PANEL` flag, rows must arrive contiguously and in ascending
/// order with exactly `k` lanes (`0..k` in order, possibly split across
/// bundles), and each chain must close with `END_OF_ROW`.
/// Trusted-caller wrapper over [`try_stream_panel_to_dense`].
pub fn stream_panel_to_dense(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    k: usize,
) -> Result<Vec<Val>> {
    Ok(try_stream_panel_to_dense(stream, lo, hi, nrows, k)?)
}

/// Fallible form of [`stream_panel_to_dense`].
pub fn try_stream_panel_to_dense(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    k: usize,
) -> std::result::Result<Vec<Val>, RirError> {
    if lo > hi || hi > stream.n_bundles() {
        return Err(RirError::SegmentOutOfBounds { lo, hi, n_bundles: stream.n_bundles() });
    }
    if k == 0 {
        if lo != hi {
            return Err(RirError::PanelZeroWidthNonEmpty);
        }
        return Ok(Vec::new());
    }
    let mut asm = PanelAssembler::new(nrows, k);
    for i in lo..hi {
        let b = stream.bundle(i);
        asm.begin_bundle(i, b.shared, b.flags)?;
        for (&c, &v) in b.cols.iter().zip(b.vals) {
            asm.lane(c, v)?;
        }
        asm.end_bundle(b.flags)?;
    }
    asm.finish()
}

/// Reassemble a CSR matrix straight from an untrusted serialized word
/// stream (the [`super::layout`] wire form), verifying per-bundle CRC32
/// checksums where [`BundleFlags::CHECKSUM`] is set. Total over arbitrary
/// input — truncation, bad extents and corruption all return [`RirError`].
pub fn try_words_to_csr(
    words: &[u32],
    nrows: usize,
    ncols: usize,
) -> std::result::Result<Csr, RirError> {
    let mut asm = RowAssembler::new(nrows, ncols);
    let mut cur = WireCursor::new(words);
    while let Some(b) = cur.next() {
        let b = b?;
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.begin_bundle(b.shared)?;
        for pair in b.payload.pairs().chunks_exact(2) {
            asm.elem(pair[0], f32::from_bits(pair[1]))?;
        }
        asm.end_bundle(b.shared, b.flags)?;
    }
    asm.finish()
}

/// Reassemble one tenant's CSR from bundles `[lo, hi)` of an untrusted
/// serialized multi-job word stream. Bundle indices count every bundle in
/// the stream, in order — the same boundaries
/// [`BundleStream::encode_csr_jobs`] returns. The whole stream is walked
/// (extent and checksum validation cover out-of-segment bundles too, as
/// the input controller's DMA does), but only the segment is assembled.
pub fn try_words_segment_to_csr(
    words: &[u32],
    lo: usize,
    hi: usize,
    nrows: usize,
    ncols: usize,
) -> std::result::Result<Csr, RirError> {
    let mut asm = RowAssembler::new(nrows, ncols);
    let mut cur = WireCursor::new(words);
    let mut n_bundles = 0usize;
    while let Some(b) = cur.next() {
        let b = b?;
        n_bundles += 1;
        if b.index < lo || b.index >= hi || b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.begin_bundle(b.shared)?;
        for pair in b.payload.pairs().chunks_exact(2) {
            asm.elem(pair[0], f32::from_bits(pair[1]))?;
        }
        asm.end_bundle(b.shared, b.flags)?;
    }
    if lo > hi || hi > n_bundles {
        return Err(RirError::SegmentOutOfBounds { lo, hi, n_bundles });
    }
    asm.finish()
}

/// Reassemble the dense panel from bundles `[lo, hi)` of an untrusted
/// serialized SpMM word stream — the wire-level form of
/// [`try_stream_panel_to_dense`].
pub fn try_words_panel_to_dense(
    words: &[u32],
    lo: usize,
    hi: usize,
    nrows: usize,
    k: usize,
) -> std::result::Result<Vec<Val>, RirError> {
    let mut asm = if k == 0 { None } else { Some(PanelAssembler::new(nrows, k)) };
    let mut cur = WireCursor::new(words);
    let mut n_bundles = 0usize;
    while let Some(b) = cur.next() {
        let b = b?;
        n_bundles += 1;
        if b.index < lo || b.index >= hi {
            continue;
        }
        let Some(asm) = asm.as_mut() else {
            return Err(RirError::PanelZeroWidthNonEmpty);
        };
        asm.begin_bundle(b.index, b.shared, b.flags)?;
        for pair in b.payload.pairs().chunks_exact(2) {
            asm.lane(pair[0], f32::from_bits(pair[1]))?;
        }
        asm.end_bundle(b.flags)?;
    }
    if lo > hi || hi > n_bundles {
        return Err(RirError::SegmentOutOfBounds { lo, hi, n_bundles });
    }
    match asm {
        None => Ok(Vec::new()),
        Some(asm) => asm.finish(),
    }
}

/// Payload of a wire bundle after extent/CRC validation: raw bundles
/// borrow their words straight from the stream; sectioned (BITMAP /
/// FIXED_POINT) bundles own the expanded pair words the hardware expander
/// would emit.
enum WirePayload<'a> {
    Raw(&'a [u32]),
    Expanded(Vec<u32>),
}

impl WirePayload<'_> {
    /// The payload as interleaved `(distinct, value-bits)` pair words
    /// (`(row, start, end)` triples for schedule bundles, which callers
    /// skip before reading pairs).
    fn pairs(&self) -> &[u32] {
        match self {
            WirePayload::Raw(w) => w,
            WirePayload::Expanded(v) => v,
        }
    }
}

/// One bundle as it appears on the wire: decoded header fields plus its
/// payload, expanded back to raw pairs when a compressed encoding was
/// negotiated (the compression flags are stripped alongside, mirroring
/// [`layout::try_deserialize`]). The CRC32 word, when present, has
/// already been verified and is not included.
struct WireBundle<'a> {
    index: usize,
    shared: Idx,
    flags: BundleFlags,
    payload: WirePayload<'a>,
}

/// Walks a serialized word stream bundle by bundle, validating payload
/// extents and per-bundle checksums before handing any payload out; never
/// indexes past the slice, so arbitrary byte garbage is safe to feed in.
/// Sizing, CRC verification and sectioned-payload expansion all go through
/// the shared [`layout`] helpers, so this walker cannot drift from
/// [`layout::try_deserialize`].
struct WireCursor<'a> {
    words: &'a [u32],
    p: usize,
    index: usize,
}

impl<'a> WireCursor<'a> {
    fn new(words: &'a [u32]) -> Self {
        WireCursor { words, p: 0, index: 0 }
    }

    #[allow(clippy::should_implement_trait)] // fallible streaming iterator
    fn next(&mut self) -> Option<std::result::Result<WireBundle<'a>, RirError>> {
        if self.p >= self.words.len() {
            return None;
        }
        let ext = match layout::bundle_extent(self.words, self.p, self.index) {
            Ok(ext) => ext,
            Err(e) => return Some(Err(e)),
        };
        if let Err(e) = layout::verify_bundle_crc(self.words, self.p, &ext, self.index) {
            return Some(Err(e));
        }
        let raw = &self.words[self.p + 2..self.p + 2 + ext.payload_words];
        let (payload, flags) = if !ext.flags.metadata_only() && ext.flags.sectioned() {
            match layout::expand_sectioned_payload(raw, ext.count, ext.flags, self.index) {
                Ok(pairs) => (
                    WirePayload::Expanded(pairs),
                    ext.flags.without(BundleFlags::BITMAP).without(BundleFlags::FIXED_POINT),
                ),
                Err(e) => return Some(Err(e)),
            }
        } else {
            (WirePayload::Raw(raw), ext.flags)
        };
        let b = WireBundle { index: self.index, shared: ext.shared, flags, payload };
        self.p += ext.total_words;
        self.index += 1;
        Some(Ok(b))
    }
}

/// Shared row-reassembly state: enforces the stream invariants (row chains
/// contiguous, one `END_OF_ROW` per chain, rows in ascending order).
struct RowAssembler {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<usize>,
    cols: Vec<Idx>,
    vals: Vec<Val>,
    current_row: Option<Idx>,
    next_row_fill: usize, // rows completed so far
}

impl RowAssembler {
    fn new(nrows: usize, ncols: usize) -> Self {
        RowAssembler {
            nrows,
            ncols,
            row_ptr: vec![0usize; nrows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
            current_row: None,
            next_row_fill: 0,
        }
    }

    fn begin_bundle(&mut self, shared: Idx) -> std::result::Result<(), RirError> {
        match self.current_row {
            None => self.current_row = Some(shared),
            Some(r) => {
                if r != shared {
                    return Err(RirError::InterleavedRows { open: r, found: shared });
                }
            }
        }
        if (shared as usize) >= self.nrows {
            return Err(RirError::RowOutOfBounds { row: shared, nrows: self.nrows });
        }
        Ok(())
    }

    fn elem(&mut self, c: Idx, v: Val) -> std::result::Result<(), RirError> {
        if (c as usize) >= self.ncols {
            return Err(RirError::ColumnOutOfBounds { col: c, ncols: self.ncols });
        }
        self.cols.push(c);
        self.vals.push(v);
        Ok(())
    }

    fn end_bundle(&mut self, shared: Idx, flags: BundleFlags) -> std::result::Result<(), RirError> {
        if flags.end_of_row() {
            let r = shared as usize;
            if r < self.next_row_fill {
                return Err(RirError::RowOrder { row: shared });
            }
            // fill row_ptr for any skipped (absent) rows, then this one
            for rr in self.next_row_fill..=r {
                self.row_ptr[rr + 1] = if rr == r { self.cols.len() } else { self.row_ptr[rr] };
            }
            // empty rows between bundles have their ptr equal to previous
            self.row_ptr[r + 1] = self.cols.len();
            self.next_row_fill = r + 1;
            self.current_row = None;
        }
        Ok(())
    }

    fn push(
        &mut self,
        shared: Idx,
        flags: BundleFlags,
        distinct: &[Idx],
        values: &[Val],
    ) -> std::result::Result<(), RirError> {
        self.begin_bundle(shared)?;
        for (&c, &v) in distinct.iter().zip(values) {
            self.elem(c, v)?;
        }
        self.end_bundle(shared, flags)
    }

    fn finish(mut self) -> std::result::Result<Csr, RirError> {
        if let Some(r) = self.current_row {
            return Err(RirError::EndedMidRow { row: r });
        }
        for rr in self.next_row_fill..self.nrows {
            self.row_ptr[rr + 1] = self.row_ptr[rr];
        }
        let m = Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            row_ptr: self.row_ptr,
            cols: self.cols,
            vals: self.vals,
        };
        m.validate().map_err(|e| RirError::InvalidCsr(format!("{e:#}")))?;
        Ok(m)
    }
}

/// Shared dense-panel reassembly state (mirrors the on-chip panel RAM's
/// write-port checks): rows ascend contiguously, lanes run `0..k` in
/// order, each row chain closes with `END_OF_ROW`.
struct PanelAssembler {
    nrows: usize,
    k: usize,
    x: Vec<Val>,
    row: usize,  // next row expected to *finish*
    lane: usize, // next lane expected within the open row
}

impl PanelAssembler {
    fn new(nrows: usize, k: usize) -> Self {
        debug_assert!(k > 0);
        PanelAssembler { nrows, k, x: vec![0 as Val; nrows * k], row: 0, lane: 0 }
    }

    fn begin_bundle(
        &mut self,
        index: usize,
        shared: Idx,
        flags: BundleFlags,
    ) -> std::result::Result<(), RirError> {
        if !flags.dense_panel() {
            return Err(RirError::NotAPanelBundle { bundle: index });
        }
        if (shared as usize) != self.row {
            return Err(RirError::PanelRowOrder { shared, expected: self.row });
        }
        if self.row >= self.nrows {
            return Err(RirError::PanelRowOutOfBounds { row: self.row, nrows: self.nrows });
        }
        Ok(())
    }

    fn lane(&mut self, c: Idx, v: Val) -> std::result::Result<(), RirError> {
        if (c as usize) != self.lane {
            return Err(RirError::PanelLaneOrder { lane: c, expected: self.lane });
        }
        if self.lane >= self.k {
            return Err(RirError::PanelLaneOverflow { k: self.k });
        }
        self.x[self.row * self.k + self.lane] = v;
        self.lane += 1;
        Ok(())
    }

    fn end_bundle(&mut self, flags: BundleFlags) -> std::result::Result<(), RirError> {
        if flags.end_of_row() {
            if self.lane != self.k {
                return Err(RirError::PanelRowWidth { row: self.row, lanes: self.lane, k: self.k });
            }
            self.row += 1;
            self.lane = 0;
        }
        Ok(())
    }

    fn finish(self) -> std::result::Result<Vec<Val>, RirError> {
        if self.lane != 0 {
            return Err(RirError::PanelEndedMidRow { row: self.row });
        }
        if self.row != self.nrows {
            return Err(RirError::PanelRowCount { rows: self.row, nrows: self.nrows });
        }
        Ok(self.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::bundle::{BundleFlags, RlTriple};
    use crate::rir::encode::csr_to_bundles;
    use crate::rir::layout::{serialize_stream, serialize_stream_checksummed};
    use crate::sparse::gen;

    #[test]
    fn roundtrip_random() {
        for seed in 0..5u64 {
            let m = gen::random_uniform(20, 30, 120, seed);
            let bundles = csr_to_bundles(&m, 7); // non-default size, forces splits
            let back = bundles_to_csr(&bundles, 20, 30).unwrap();
            assert_eq!(back, m, "seed {seed}");
        }
    }

    #[test]
    fn roundtrip_with_empty_rows_and_big_rows() {
        let mut m = gen::power_law(40, 600, 3);
        // force a guaranteed-empty row
        let start = m.row_ptr[10];
        let end = m.row_ptr[11];
        m.cols.drain(start..end);
        m.vals.drain(start..end);
        for p in m.row_ptr.iter_mut().skip(11) {
            *p -= end - start;
        }
        m.validate().unwrap();
        let bundles = csr_to_bundles(&m, 32);
        let back = bundles_to_csr(&bundles, m.nrows, m.ncols).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn metadata_bundles_skipped() {
        let m = gen::random_uniform(4, 4, 8, 9);
        let mut bundles = csr_to_bundles(&m, 32);
        bundles.insert(
            2,
            Bundle::schedule(0, vec![RlTriple { row: 1, start: 0, end: 4 }], BundleFlags::default()),
        );
        let back = bundles_to_csr(&bundles, 4, 4).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn interleaved_rows_rejected() {
        let bundles = vec![
            Bundle::data(0, vec![0], vec![1.0], BundleFlags::default()), // row 0, not finished
            Bundle::data(1, vec![1], vec![1.0], BundleFlags::default().with(BundleFlags::END_OF_ROW)),
        ];
        assert!(bundles_to_csr(&bundles, 2, 2).is_err());
    }

    #[test]
    fn truncated_stream_rejected() {
        let bundles = vec![Bundle::data(0, vec![0], vec![1.0], BundleFlags::default())];
        assert!(bundles_to_csr(&bundles, 1, 1).is_err());
    }

    #[test]
    fn stream_roundtrip_matches_source() {
        for seed in 0..3u64 {
            let m = gen::power_law(25, 300, seed);
            let s = BundleStream::from_csr(&m, 5);
            assert_eq!(stream_to_csr(&s, m.nrows, m.ncols).unwrap(), m, "seed {seed}");
        }
    }

    #[test]
    fn stream_with_empty_rows_roundtrips() {
        let mut m = crate::sparse::Csr::new(4, 4);
        m.cols = vec![1, 3];
        m.vals = vec![2.0, -1.0];
        m.row_ptr = vec![0, 0, 2, 2, 2];
        m.validate().unwrap();
        let s = BundleStream::from_csr(&m, 32);
        assert_eq!(stream_to_csr(&s, 4, 4).unwrap(), m);
    }

    #[test]
    fn job_segments_extract_each_tenant() {
        let m0 = gen::power_law(18, 200, 21);
        let m1 = crate::sparse::Csr::new(0, 6); // empty tenant
        let m2 = gen::random_uniform(9, 14, 60, 22);
        let jobs = [&m0, &m1, &m2];
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&jobs, 8);
        for (j, m) in jobs.iter().enumerate() {
            let back =
                stream_segment_to_csr(&s, bounds[j], bounds[j + 1], m.nrows, m.ncols).unwrap();
            assert_eq!(&back, *m, "job {j}");
        }
        // a segment cut mid-row-chain is rejected, not silently absorbed
        let mut wide = crate::sparse::Csr::new(1, 30);
        wide.cols = (0..20).collect();
        wide.vals = vec![1.0; 20];
        wide.row_ptr = vec![0, 20];
        wide.validate().unwrap();
        let mut s2 = BundleStream::new();
        let b2 = s2.encode_csr_jobs(&[&wide], 8); // 3-bundle chain
        assert!(b2[1] >= 3);
        assert!(stream_segment_to_csr(&s2, 0, b2[1] - 1, 1, 30).is_err());
        // out-of-bounds segment rejected
        assert!(stream_segment_to_csr(&s, 0, s.n_bundles() + 1, 5, 5).is_err());
    }

    #[test]
    fn panel_stream_roundtrips_both_halves() {
        let m = gen::power_law(14, 160, 41);
        let k = 6usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| (i as f32 * 0.3).sin()).collect();
        for bs in [1usize, 4, 16] {
            let mut s = BundleStream::new();
            let boundary = s.encode_csr_with_panel(&m, &x, k, bs);
            // sparse assembler skips the panel and recovers A
            assert_eq!(stream_to_csr(&s, m.nrows, m.ncols).unwrap(), m, "bs {bs}");
            // panel assembler recovers X bit-for-bit
            let back = stream_panel_to_dense(&s, boundary, s.n_bundles(), m.ncols, k).unwrap();
            assert_eq!(back, x, "bs {bs}");
        }
    }

    #[test]
    fn panel_decode_rejects_malformed_segments() {
        let m = gen::random_uniform(6, 8, 20, 42);
        let k = 4usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, k, 16);
        let n = s.n_bundles();
        // segment including sparse bundles: not all DENSE_PANEL
        assert!(stream_panel_to_dense(&s, 0, n, m.ncols, k).is_err());
        // truncated panel: ends mid-row set (missing rows)
        assert!(stream_panel_to_dense(&s, boundary, n - 1, m.ncols, k).is_err());
        // wrong declared width
        assert!(stream_panel_to_dense(&s, boundary, n, m.ncols, k + 1).is_err());
        // out-of-bounds segment
        assert!(stream_panel_to_dense(&s, boundary, n + 1, m.ncols, k).is_err());
        // zero-width panel: empty segment ok, non-empty rejected
        assert_eq!(stream_panel_to_dense(&s, boundary, boundary, 0, 0).unwrap(), vec![]);
        assert!(stream_panel_to_dense(&s, boundary, n, m.ncols, 0).is_err());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let bundles = vec![Bundle::data(
            0,
            vec![9],
            vec![1.0],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        )];
        assert!(bundles_to_csr(&bundles, 1, 2).is_err());
    }

    #[test]
    fn words_roundtrip_plain_and_checksummed() {
        for seed in 0..3u64 {
            let m = gen::power_law(25, 300, seed);
            let s = BundleStream::from_csr(&m, 5);
            let plain = serialize_stream(&s);
            assert_eq!(try_words_to_csr(&plain, m.nrows, m.ncols).unwrap(), m, "seed {seed}");
            let protected = serialize_stream_checksummed(&s);
            assert_eq!(
                try_words_to_csr(&protected, m.nrows, m.ncols).unwrap(),
                m,
                "checksummed seed {seed}"
            );
        }
    }

    #[test]
    fn words_segment_extracts_each_tenant() {
        let m0 = gen::power_law(18, 200, 31);
        let m1 = crate::sparse::Csr::new(0, 6);
        let m2 = gen::random_uniform(9, 14, 60, 32);
        let jobs = [&m0, &m1, &m2];
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&jobs, 8);
        for words in [serialize_stream(&s), serialize_stream_checksummed(&s)] {
            for (j, m) in jobs.iter().enumerate() {
                let back =
                    try_words_segment_to_csr(&words, bounds[j], bounds[j + 1], m.nrows, m.ncols)
                        .unwrap();
                assert_eq!(&back, *m, "job {j}");
            }
            assert!(matches!(
                try_words_segment_to_csr(&words, 0, s.n_bundles() + 1, 5, 5),
                Err(RirError::SegmentOutOfBounds { .. })
            ));
        }
    }

    #[test]
    fn words_panel_roundtrips() {
        let m = gen::power_law(14, 160, 43);
        let k = 6usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, k, 4);
        for words in [serialize_stream(&s), serialize_stream_checksummed(&s)] {
            let back =
                try_words_panel_to_dense(&words, boundary, s.n_bundles(), m.ncols, k).unwrap();
            assert_eq!(back, x);
            // the sparse decoder skips the panel segment
            assert_eq!(try_words_to_csr(&words, m.nrows, m.ncols).unwrap(), m);
            // zero-width panel over a non-empty segment is rejected
            assert!(matches!(
                try_words_panel_to_dense(&words, boundary, s.n_bundles(), m.ncols, 0),
                Err(RirError::PanelZeroWidthNonEmpty)
            ));
        }
    }

    #[test]
    fn words_decoders_reject_truncation_at_every_cut() {
        let m = gen::random_uniform(8, 8, 30, 44);
        let s = BundleStream::from_csr(&m, 4);
        let words = serialize_stream_checksummed(&s);
        // every strict prefix must be handled without panicking (a cut on
        // a bundle boundary may legally decode to a shorter matrix; a cut
        // inside a bundle must error)
        for cut in 0..words.len() {
            let w = &words[..cut];
            let _ = try_words_to_csr(w, m.nrows, m.ncols);
            let _ = try_words_segment_to_csr(w, 0, 1, m.nrows, m.ncols);
            let _ = try_words_panel_to_dense(w, 0, 1, m.nrows, 4);
        }
        assert!(matches!(
            try_words_to_csr(&words[..words.len() - 1], m.nrows, m.ncols),
            Err(RirError::TruncatedPayload { .. })
        ));
    }

    #[test]
    fn words_decoders_handle_compressed_encodings() {
        use crate::rir::layout::{fx_max_abs_error, serialize_stream_encoded, StreamEncoding};
        let m = gen::power_law(20, 300, 51);
        let s = BundleStream::from_csr(&m, 8);
        for ck in [false, true] {
            // bitmap is lossless: the decoded CSR is bit-identical
            let words = serialize_stream_encoded(&s, StreamEncoding::Bitmap, ck);
            assert_eq!(try_words_to_csr(&words, m.nrows, m.ncols).unwrap(), m, "ck {ck}");
            // fixed point: same pattern, values within the documented
            // bound (every bundle's scale ≤ the global max |v|, so the
            // global bound is conservative)
            let words = serialize_stream_encoded(&s, StreamEncoding::BitmapFx, ck);
            let back = try_words_to_csr(&words, m.nrows, m.ncols).unwrap();
            assert_eq!(back.row_ptr, m.row_ptr, "ck {ck}");
            assert_eq!(back.cols, m.cols, "ck {ck}");
            let bound = fx_max_abs_error(m.vals.iter().fold(0f32, |mx, v| mx.max(v.abs())));
            for (&v, &vhat) in m.vals.iter().zip(&back.vals) {
                let err = (v as f64 - vhat as f64).abs();
                assert!(err <= bound, "ck {ck}: err {err} > bound {bound}");
            }
            // truncating inside a compressed bundle errors, never panics
            for cut in 0..words.len() {
                let _ = try_words_to_csr(&words[..cut], m.nrows, m.ncols);
            }
        }
    }

    #[test]
    fn words_segment_and_panel_decode_compressed_streams() {
        use crate::rir::layout::{serialize_stream_encoded, StreamEncoding};
        // multi-job segment over a compressed wire form
        let m0 = gen::power_law(15, 150, 53);
        let m1 = gen::random_uniform(7, 12, 40, 54);
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&[&m0, &m1], 8);
        let words = serialize_stream_encoded(&s, StreamEncoding::Bitmap, true);
        for (j, m) in [&m0, &m1].iter().enumerate() {
            let back = try_words_segment_to_csr(&words, bounds[j], bounds[j + 1], m.nrows, m.ncols)
                .unwrap();
            assert_eq!(&back, *m, "job {j}");
        }
        // dense panel: contiguous lane chains compress under bitmaps and
        // decode back losslessly; the sparse decoder still skips them
        let mp = gen::power_law(10, 100, 55);
        let k = 8usize;
        let x: Vec<f32> = (0..mp.ncols * k).map(|i| (i as f32 * 0.2).sin()).collect();
        let mut sp = BundleStream::new();
        let boundary = sp.encode_csr_with_panel(&mp, &x, k, 16);
        let pw = serialize_stream_encoded(&sp, StreamEncoding::Bitmap, true);
        let back = try_words_panel_to_dense(&pw, boundary, sp.n_bundles(), mp.ncols, k).unwrap();
        assert_eq!(back, x, "bitmap lanes are lossless");
        assert_eq!(try_words_to_csr(&pw, mp.nrows, mp.ncols).unwrap(), mp);
    }
}
