//! The paper's `decompress` routine: RIR bundles → CSR.
//!
//! "To support any sparse format, one has to provide compress and
//! decompress routines" (§II). Decoding validates the stream invariants the
//! FPGA input controller relies on: bundles of one row are contiguous, each
//! row chain ends with exactly one `END_OF_ROW`, metadata-only bundles
//! carry no matrix data.

use anyhow::{bail, ensure, Result};

use crate::sparse::{Csr, Idx, Val};

use super::bundle::{Bundle, Payload};

/// Reassemble a CSR matrix from a bundle stream produced by
/// [`super::encode::csr_to_bundles`].
///
/// `nrows`/`ncols` give the target shape (the stream itself is
/// shape-agnostic, exactly like the hardware). Metadata-only bundles are
/// skipped (they carry scheduling, not data).
pub fn bundles_to_csr(bundles: &[Bundle], nrows: usize, ncols: usize) -> Result<Csr> {
    let mut row_ptr = vec![0usize; nrows + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    let mut current_row: Option<Idx> = None;
    let mut next_row_fill = 0usize; // rows completed so far

    for b in bundles {
        if b.flags.metadata_only() {
            continue;
        }
        let (distinct, values) = match &b.payload {
            Payload::Data { distinct, values } => (distinct, values),
            Payload::Schedule { .. } => {
                bail!("schedule payload without METADATA_ONLY flag")
            }
        };
        match current_row {
            None => current_row = Some(b.shared),
            Some(r) => ensure!(
                r == b.shared,
                "bundle for row {} interleaved into unfinished row {r}",
                b.shared
            ),
        }
        ensure!((b.shared as usize) < nrows, "row {} out of bounds", b.shared);
        for (&c, &v) in distinct.iter().zip(values) {
            ensure!((c as usize) < ncols, "column {c} out of bounds");
            cols.push(c);
            vals.push(v);
        }
        if b.flags.end_of_row() {
            let r = b.shared as usize;
            ensure!(
                r >= next_row_fill,
                "row {r} completed twice (or rows out of order)"
            );
            // fill row_ptr for any skipped (absent) rows, then this one
            for rr in next_row_fill..=r {
                row_ptr[rr + 1] = if rr == r { cols.len() } else { row_ptr[rr] };
            }
            // empty rows between bundles have their ptr equal to previous
            row_ptr[r + 1] = cols.len();
            next_row_fill = r + 1;
            current_row = None;
        }
    }
    ensure!(current_row.is_none(), "stream ended mid-row {current_row:?}");
    for rr in next_row_fill..nrows {
        row_ptr[rr + 1] = row_ptr[rr];
    }
    let m = Csr { nrows, ncols, row_ptr, cols, vals };
    m.validate()?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::bundle::{BundleFlags, RlTriple};
    use crate::rir::encode::csr_to_bundles;
    use crate::sparse::gen;

    #[test]
    fn roundtrip_random() {
        for seed in 0..5u64 {
            let m = gen::random_uniform(20, 30, 120, seed);
            let bundles = csr_to_bundles(&m, 7); // non-default size, forces splits
            let back = bundles_to_csr(&bundles, 20, 30).unwrap();
            assert_eq!(back, m, "seed {seed}");
        }
    }

    #[test]
    fn roundtrip_with_empty_rows_and_big_rows() {
        let mut m = gen::power_law(40, 600, 3);
        // force a guaranteed-empty row
        let start = m.row_ptr[10];
        let end = m.row_ptr[11];
        m.cols.drain(start..end);
        m.vals.drain(start..end);
        for p in m.row_ptr.iter_mut().skip(11) {
            *p -= end - start;
        }
        m.validate().unwrap();
        let bundles = csr_to_bundles(&m, 32);
        let back = bundles_to_csr(&bundles, m.nrows, m.ncols).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn metadata_bundles_skipped() {
        let m = gen::random_uniform(4, 4, 8, 9);
        let mut bundles = csr_to_bundles(&m, 32);
        bundles.insert(
            2,
            Bundle::schedule(0, vec![RlTriple { row: 1, start: 0, end: 4 }], BundleFlags::default()),
        );
        let back = bundles_to_csr(&bundles, 4, 4).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn interleaved_rows_rejected() {
        let bundles = vec![
            Bundle::data(0, vec![0], vec![1.0], BundleFlags::default()), // row 0, not finished
            Bundle::data(1, vec![1], vec![1.0], BundleFlags::default().with(BundleFlags::END_OF_ROW)),
        ];
        assert!(bundles_to_csr(&bundles, 2, 2).is_err());
    }

    #[test]
    fn truncated_stream_rejected() {
        let bundles = vec![Bundle::data(0, vec![0], vec![1.0], BundleFlags::default())];
        assert!(bundles_to_csr(&bundles, 1, 1).is_err());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let bundles = vec![Bundle::data(
            0,
            vec![9],
            vec![1.0],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        )];
        assert!(bundles_to_csr(&bundles, 1, 2).is_err());
    }
}
