//! The paper's `decompress` routine: RIR bundles → CSR.
//!
//! "To support any sparse format, one has to provide compress and
//! decompress routines" (§II). Decoding validates the stream invariants the
//! FPGA input controller relies on: bundles of one row are contiguous, each
//! row chain ends with exactly one `END_OF_ROW`, metadata-only bundles
//! carry no matrix data. Dense-panel bundles (the SpMM right-hand-side
//! block, [`BundleStream::encode_csr_with_panel`]) are skipped by the
//! sparse assemblers — they route to the on-chip panel RAM, not the CAMs —
//! and reassembled by [`stream_panel_to_dense`].

use anyhow::{bail, ensure, Result};

use crate::sparse::{Csr, Idx, Val};

use super::bundle::{Bundle, BundleFlags, Payload};
use super::encode::BundleStream;

/// Reassemble a CSR matrix from a bundle stream produced by
/// [`super::encode::csr_to_bundles`].
///
/// `nrows`/`ncols` give the target shape (the stream itself is
/// shape-agnostic, exactly like the hardware). Metadata-only bundles are
/// skipped (they carry scheduling, not data).
pub fn bundles_to_csr(bundles: &[Bundle], nrows: usize, ncols: usize) -> Result<Csr> {
    let mut asm = RowAssembler::new(nrows, ncols);
    for b in bundles {
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        let (distinct, values) = match &b.payload {
            Payload::Data { distinct, values } => (distinct, values),
            Payload::Schedule { .. } => {
                bail!("schedule payload without METADATA_ONLY flag")
            }
        };
        asm.push(b.shared, b.flags, distinct, values)?;
    }
    asm.finish()
}

/// Reassemble a CSR matrix from a flat [`BundleStream`] arena — identical
/// validation to [`bundles_to_csr`] without materializing boxed bundles.
pub fn stream_to_csr(stream: &BundleStream, nrows: usize, ncols: usize) -> Result<Csr> {
    let mut asm = RowAssembler::new(nrows, ncols);
    for b in stream.iter() {
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.push(b.shared, b.flags, b.cols, b.vals)?;
    }
    asm.finish()
}

/// Reassemble one tenant's CSR from its bundle segment `[lo, hi)` of a
/// shared multi-job stream (the boundaries returned by
/// [`BundleStream::encode_csr_jobs`]). Validation is identical to
/// [`stream_to_csr`] — the segment must be a self-contained stream.
pub fn stream_segment_to_csr(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    ncols: usize,
) -> Result<Csr> {
    ensure!(
        lo <= hi && hi <= stream.n_bundles(),
        "segment [{lo}, {hi}) out of bounds (stream has {} bundles)",
        stream.n_bundles()
    );
    let mut asm = RowAssembler::new(nrows, ncols);
    for i in lo..hi {
        let b = stream.bundle(i);
        if b.flags.metadata_only() || b.flags.dense_panel() {
            continue;
        }
        asm.push(b.shared, b.flags, b.cols, b.vals)?;
    }
    asm.finish()
}

/// Reassemble the dense right-hand-side panel X from its bundle segment
/// `[lo, hi)` of a combined SpMM stream (the boundary returned by
/// [`BundleStream::encode_csr_with_panel`]).
///
/// `nrows` is the panel's row count (= the sparse matrix's column count)
/// and `k` its lane width; the result is row-major `nrows × k`, exactly
/// the layout [`crate::kernels::spmm::spmm`] consumes. Validation mirrors
/// the sparse assembler's: every bundle in the segment must carry the
/// `DENSE_PANEL` flag, rows must arrive contiguously and in ascending
/// order with exactly `k` lanes (`0..k` in order, possibly split across
/// bundles), and each chain must close with `END_OF_ROW`.
pub fn stream_panel_to_dense(
    stream: &BundleStream,
    lo: usize,
    hi: usize,
    nrows: usize,
    k: usize,
) -> Result<Vec<Val>> {
    ensure!(
        lo <= hi && hi <= stream.n_bundles(),
        "panel segment [{lo}, {hi}) out of bounds (stream has {} bundles)",
        stream.n_bundles()
    );
    if k == 0 {
        ensure!(lo == hi, "zero-width panel cannot carry bundles");
        return Ok(Vec::new());
    }
    let mut x = vec![0 as Val; nrows * k];
    let mut row = 0usize; // next row expected to *finish*
    let mut lane = 0usize; // next lane expected within the open row
    for i in lo..hi {
        let b = stream.bundle(i);
        ensure!(b.flags.dense_panel(), "bundle {i} in panel segment lacks DENSE_PANEL");
        ensure!((b.shared as usize) == row, "panel row {} out of order (expected {row})", b.shared);
        ensure!(row < nrows, "panel row {row} out of bounds");
        for (&c, &v) in b.cols.iter().zip(b.vals) {
            ensure!((c as usize) == lane, "panel lane {c} out of order (expected {lane})");
            ensure!(lane < k, "panel lane {lane} exceeds width {k}");
            x[row * k + lane] = v;
            lane += 1;
        }
        if b.flags.end_of_row() {
            ensure!(lane == k, "panel row {row} closed with {lane} of {k} lanes");
            row += 1;
            lane = 0;
        }
    }
    ensure!(lane == 0, "panel segment ended mid-row {row}");
    ensure!(row == nrows, "panel segment carried {row} of {nrows} rows");
    Ok(x)
}

/// Shared row-reassembly state: enforces the stream invariants (row chains
/// contiguous, one `END_OF_ROW` per chain, rows in ascending order).
struct RowAssembler {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<usize>,
    cols: Vec<Idx>,
    vals: Vec<Val>,
    current_row: Option<Idx>,
    next_row_fill: usize, // rows completed so far
}

impl RowAssembler {
    fn new(nrows: usize, ncols: usize) -> Self {
        RowAssembler {
            nrows,
            ncols,
            row_ptr: vec![0usize; nrows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
            current_row: None,
            next_row_fill: 0,
        }
    }

    fn push(
        &mut self,
        shared: Idx,
        flags: BundleFlags,
        distinct: &[Idx],
        values: &[Val],
    ) -> Result<()> {
        match self.current_row {
            None => self.current_row = Some(shared),
            Some(r) => ensure!(
                r == shared,
                "bundle for row {shared} interleaved into unfinished row {r}"
            ),
        }
        ensure!((shared as usize) < self.nrows, "row {shared} out of bounds");
        for (&c, &v) in distinct.iter().zip(values) {
            ensure!((c as usize) < self.ncols, "column {c} out of bounds");
            self.cols.push(c);
            self.vals.push(v);
        }
        if flags.end_of_row() {
            let r = shared as usize;
            ensure!(
                r >= self.next_row_fill,
                "row {r} completed twice (or rows out of order)"
            );
            // fill row_ptr for any skipped (absent) rows, then this one
            for rr in self.next_row_fill..=r {
                self.row_ptr[rr + 1] = if rr == r { self.cols.len() } else { self.row_ptr[rr] };
            }
            // empty rows between bundles have their ptr equal to previous
            self.row_ptr[r + 1] = self.cols.len();
            self.next_row_fill = r + 1;
            self.current_row = None;
        }
        Ok(())
    }

    fn finish(mut self) -> Result<Csr> {
        ensure!(
            self.current_row.is_none(),
            "stream ended mid-row {:?}",
            self.current_row
        );
        for rr in self.next_row_fill..self.nrows {
            self.row_ptr[rr + 1] = self.row_ptr[rr];
        }
        let m = Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            row_ptr: self.row_ptr,
            cols: self.cols,
            vals: self.vals,
        };
        m.validate()?;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::bundle::{BundleFlags, RlTriple};
    use crate::rir::encode::csr_to_bundles;
    use crate::sparse::gen;

    #[test]
    fn roundtrip_random() {
        for seed in 0..5u64 {
            let m = gen::random_uniform(20, 30, 120, seed);
            let bundles = csr_to_bundles(&m, 7); // non-default size, forces splits
            let back = bundles_to_csr(&bundles, 20, 30).unwrap();
            assert_eq!(back, m, "seed {seed}");
        }
    }

    #[test]
    fn roundtrip_with_empty_rows_and_big_rows() {
        let mut m = gen::power_law(40, 600, 3);
        // force a guaranteed-empty row
        let start = m.row_ptr[10];
        let end = m.row_ptr[11];
        m.cols.drain(start..end);
        m.vals.drain(start..end);
        for p in m.row_ptr.iter_mut().skip(11) {
            *p -= end - start;
        }
        m.validate().unwrap();
        let bundles = csr_to_bundles(&m, 32);
        let back = bundles_to_csr(&bundles, m.nrows, m.ncols).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn metadata_bundles_skipped() {
        let m = gen::random_uniform(4, 4, 8, 9);
        let mut bundles = csr_to_bundles(&m, 32);
        bundles.insert(
            2,
            Bundle::schedule(0, vec![RlTriple { row: 1, start: 0, end: 4 }], BundleFlags::default()),
        );
        let back = bundles_to_csr(&bundles, 4, 4).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn interleaved_rows_rejected() {
        let bundles = vec![
            Bundle::data(0, vec![0], vec![1.0], BundleFlags::default()), // row 0, not finished
            Bundle::data(1, vec![1], vec![1.0], BundleFlags::default().with(BundleFlags::END_OF_ROW)),
        ];
        assert!(bundles_to_csr(&bundles, 2, 2).is_err());
    }

    #[test]
    fn truncated_stream_rejected() {
        let bundles = vec![Bundle::data(0, vec![0], vec![1.0], BundleFlags::default())];
        assert!(bundles_to_csr(&bundles, 1, 1).is_err());
    }

    #[test]
    fn stream_roundtrip_matches_source() {
        for seed in 0..3u64 {
            let m = gen::power_law(25, 300, seed);
            let s = BundleStream::from_csr(&m, 5);
            assert_eq!(stream_to_csr(&s, m.nrows, m.ncols).unwrap(), m, "seed {seed}");
        }
    }

    #[test]
    fn stream_with_empty_rows_roundtrips() {
        let mut m = crate::sparse::Csr::new(4, 4);
        m.cols = vec![1, 3];
        m.vals = vec![2.0, -1.0];
        m.row_ptr = vec![0, 0, 2, 2, 2];
        m.validate().unwrap();
        let s = BundleStream::from_csr(&m, 32);
        assert_eq!(stream_to_csr(&s, 4, 4).unwrap(), m);
    }

    #[test]
    fn job_segments_extract_each_tenant() {
        let m0 = gen::power_law(18, 200, 21);
        let m1 = crate::sparse::Csr::new(0, 6); // empty tenant
        let m2 = gen::random_uniform(9, 14, 60, 22);
        let jobs = [&m0, &m1, &m2];
        let mut s = BundleStream::new();
        let bounds = s.encode_csr_jobs(&jobs, 8);
        for (j, m) in jobs.iter().enumerate() {
            let back =
                stream_segment_to_csr(&s, bounds[j], bounds[j + 1], m.nrows, m.ncols).unwrap();
            assert_eq!(&back, *m, "job {j}");
        }
        // a segment cut mid-row-chain is rejected, not silently absorbed
        let mut wide = crate::sparse::Csr::new(1, 30);
        wide.cols = (0..20).collect();
        wide.vals = vec![1.0; 20];
        wide.row_ptr = vec![0, 20];
        wide.validate().unwrap();
        let mut s2 = BundleStream::new();
        let b2 = s2.encode_csr_jobs(&[&wide], 8); // 3-bundle chain
        assert!(b2[1] >= 3);
        assert!(stream_segment_to_csr(&s2, 0, b2[1] - 1, 1, 30).is_err());
        // out-of-bounds segment rejected
        assert!(stream_segment_to_csr(&s, 0, s.n_bundles() + 1, 5, 5).is_err());
    }

    #[test]
    fn panel_stream_roundtrips_both_halves() {
        let m = gen::power_law(14, 160, 41);
        let k = 6usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| (i as f32 * 0.3).sin()).collect();
        for bs in [1usize, 4, 16] {
            let mut s = BundleStream::new();
            let boundary = s.encode_csr_with_panel(&m, &x, k, bs);
            // sparse assembler skips the panel and recovers A
            assert_eq!(stream_to_csr(&s, m.nrows, m.ncols).unwrap(), m, "bs {bs}");
            // panel assembler recovers X bit-for-bit
            let back = stream_panel_to_dense(&s, boundary, s.n_bundles(), m.ncols, k).unwrap();
            assert_eq!(back, x, "bs {bs}");
        }
    }

    #[test]
    fn panel_decode_rejects_malformed_segments() {
        let m = gen::random_uniform(6, 8, 20, 42);
        let k = 4usize;
        let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32).collect();
        let mut s = BundleStream::new();
        let boundary = s.encode_csr_with_panel(&m, &x, k, 16);
        let n = s.n_bundles();
        // segment including sparse bundles: not all DENSE_PANEL
        assert!(stream_panel_to_dense(&s, 0, n, m.ncols, k).is_err());
        // truncated panel: ends mid-row set (missing rows)
        assert!(stream_panel_to_dense(&s, boundary, n - 1, m.ncols, k).is_err());
        // wrong declared width
        assert!(stream_panel_to_dense(&s, boundary, n, m.ncols, k + 1).is_err());
        // out-of-bounds segment
        assert!(stream_panel_to_dense(&s, boundary, n + 1, m.ncols, k).is_err());
        // zero-width panel: empty segment ok, non-empty rejected
        assert_eq!(stream_panel_to_dense(&s, boundary, boundary, 0, 0).unwrap(), vec![]);
        assert!(stream_panel_to_dense(&s, boundary, n, m.ncols, 0).is_err());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let bundles = vec![Bundle::data(
            0,
            vec![9],
            vec![1.0],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        )];
        assert!(bundles_to_csr(&bundles, 1, 2).is_err());
    }
}
