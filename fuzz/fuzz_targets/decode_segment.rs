//! Fuzz `try_words_segment_to_csr` (per-tenant segment extraction).
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_segment(data);
});
