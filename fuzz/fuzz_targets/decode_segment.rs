//! Fuzz `try_words_segment_to_csr` (per-tenant segment extraction).
//! Seeds include BITMAP- and FIXED_POINT-encoded bundles inside the
//! extracted segment so the expander path is mutated, not just raw pairs.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_segment(data);
});
