//! Fuzz the static stream auditor: `reap lint`'s RIR pass must be total —
//! it returns a diagnostic list (possibly long) and never panics, on any
//! byte string reinterpreted as stream words.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_lint_stream(data);
});
