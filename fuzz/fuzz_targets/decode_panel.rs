//! Fuzz `try_words_panel_to_dense` (SpMM dense-panel reassembly).
//! Seeds include a FIXED_POINT dense-panel bundle so the Q1.15 lane
//! decode inside panel assembly is part of the mutation frontier.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_panel(data);
});
