//! Fuzz `try_words_panel_to_dense` (SpMM dense-panel reassembly).
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_panel(data);
});
