//! Fuzz `try_words_to_csr`: any byte string must decode or error, never
//! panic. The driver lives in the `reap` lib so the in-tree corpus test
//! replays the exact same path on stable.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_stream(data);
});
