//! Fuzz `try_words_to_csr`: any byte string must decode or error, never
//! panic. The driver lives in the `reap` lib so the in-tree corpus test
//! replays the exact same path on stable. Seeds cover raw, checksummed,
//! BITMAP (hierarchical-bitmap index section) and FIXED_POINT (Q1.15
//! value lane) bundles so mutation starts from every wire layout.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    reap::reliability::fuzz_decode_stream(data);
});
