#!/usr/bin/env python3
"""Perf gate over the BENCH_*.json trajectory files.

Compares the combined CPU pass (sum of every record's ``cpu_s``) between a
baseline results directory and a fresh one, and fails when the fresh run
regresses by more than the tolerance. Only files present on *both* sides
are compared, so a PR that adds a new benchmark is not penalized for it;
per-file breakdowns are printed for diagnosis.

Records that carry the simulated-FPGA cycle fields (``cycles_serial`` and
``cycles_db`` — the batch, compression and serving benches) are
additionally gated on those sums with their own, much tighter tolerance: the cycle model is
deterministic, so any drift is a real modeling change, not runner noise.
A small ``--cycles-tol`` (default 2%) leaves headroom for intentional
model refinements while catching accidental pricing regressions — e.g. a
double-buffer prefetch term silently lost, or stream words over-billed.

Either side may be a colon-separated list of directories holding repeated
runs; the per-file value is then the **minimum** across runs — min-of-N
is the standard defense against shared-runner scheduling noise (timing
noise on a deterministic pass is strictly additive; for the cycle sums
every run is identical and the min is a no-op).

Usage:
    python3 python/check_regression.py <baseline_dir[:dir...]> \
        <fresh_dir[:dir...]> [--tol 0.10] [--cycles-tol 0.02] \
        [--min-seconds 0.002]

Exit status: 0 when within tolerance (or nothing comparable / baseline
below the noise floor), 1 on regression, 2 on usage errors.
"""

import argparse
import glob
import json
import os
import sys


def combined_cpu_s(path):
    """Sum of cpu_s over all records of one BENCH_*.json file."""
    with open(path) as f:
        records = json.load(f)
    return sum(float(r.get("cpu_s", 0.0)) for r in records)


def bench_files(dirs_spec):
    """Map basename -> list of paths across a colon-separated dir list."""
    out = {}
    for directory in dirs_spec.split(":"):
        for p in glob.glob(os.path.join(directory, "BENCH_*.json")):
            out.setdefault(os.path.basename(p), []).append(p)
    return out


def min_cpu_s(paths):
    """Minimum combined cpu_s across repeated runs of one file."""
    return min(combined_cpu_s(p) for p in paths)


def combined_cycles(path, field):
    """Sum of one cycle field over the records that carry it, or None."""
    with open(path) as f:
        records = json.load(f)
    vals = [int(r[field]) for r in records if field in r]
    return sum(vals) if vals else None


def min_cycles(paths, field):
    """Minimum cycle sum across runs (identical runs — min is a no-op)."""
    vals = [c for c in (combined_cycles(p, field) for p in paths)
            if c is not None]
    return min(vals) if vals else None


def gate_cycles(common, base, fresh, field, tol):
    """Gate one deterministic cycle field; returns True when it holds."""
    base_total = 0
    fresh_total = 0
    for name in common:
        b = min_cycles(base[name], field)
        f = min_cycles(fresh[name], field)
        if b is None or f is None:
            continue  # bench doesn't emit this field on both sides
        base_total += b
        fresh_total += f
        print(f"  {name}: baseline {field} {b} fresh {f}")
    if base_total == 0:
        print(f"perf gate: no comparable {field} records; skipping")
        return True
    ratio = fresh_total / base_total
    print(f"perf gate: combined {field} baseline {base_total} -> "
          f"fresh {fresh_total} (ratio {ratio:.4f}, tol {1 + tol:.2f})")
    if ratio > 1.0 + tol:
        print(f"perf gate: FAIL — {field} regressed "
              f"{(ratio - 1.0) * 100:.2f}% (> {tol * 100:.0f}%); the cycle "
              f"model is deterministic, so this is a real pricing change")
        return False
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline_dir")
    ap.add_argument("fresh_dir")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed relative regression (default 0.10 = 10%%)")
    ap.add_argument("--cycles-tol", type=float, default=0.02,
                    help="allowed relative regression of the deterministic "
                         "cycles_serial / cycles_db sums (default 0.02)")
    ap.add_argument("--min-seconds", type=float, default=0.002,
                    help="baseline noise floor: below this combined time "
                         "the gate passes trivially")
    args = ap.parse_args()

    base = bench_files(args.baseline_dir)
    fresh = bench_files(args.fresh_dir)
    common = sorted(set(base) & set(fresh))
    if not common:
        print(f"perf gate: no BENCH_*.json files common to "
              f"{args.baseline_dir} and {args.fresh_dir}; nothing to compare")
        return 0

    base_total = 0.0
    fresh_total = 0.0
    for name in common:
        b = min_cpu_s(base[name])
        f = min_cpu_s(fresh[name])
        base_total += b
        fresh_total += f
        print(f"  {name}: baseline {b:.6f}s (min of {len(base[name])}) "
              f"fresh {f:.6f}s (min of {len(fresh[name])})")

    # deterministic cycle gates run regardless of the wall-clock noise
    # floor — the model has no noise to floor away
    cycles_ok = all(
        gate_cycles(common, base, fresh, field, args.cycles_tol)
        for field in ("cycles_serial", "cycles_db")
    )

    if base_total < args.min_seconds:
        print(f"perf gate: baseline combined CPU pass {base_total:.6f}s is "
              f"below the {args.min_seconds}s noise floor; passing")
        return 0 if cycles_ok else 1

    ratio = fresh_total / base_total
    print(f"perf gate: combined CPU pass baseline {base_total:.6f}s -> "
          f"fresh {fresh_total:.6f}s (ratio {ratio:.3f}, tol {1 + args.tol:.2f})")
    if ratio > 1.0 + args.tol:
        print(f"perf gate: FAIL — combined CPU pass regressed "
              f"{(ratio - 1.0) * 100:.1f}% (> {args.tol * 100:.0f}%)")
        return 1
    if not cycles_ok:
        return 1
    print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
