"""Pallas Cholesky column-update kernel vs the loop oracle, plus an
end-to-end factorization driven column-by-column through the kernel."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cholesky_update import cholesky_column_step


def run_both(kc, kv, rc, rv, av, ad, bundle, pipes):
    got_o, got_d = cholesky_column_step(kc, kv, rc, rv, av, ad, bundle=bundle, pipes=pipes)
    want_o, want_d = ref.cholesky_column_step_ref(kc, kv, rc, rv, av, ad)
    np.testing.assert_allclose(np.asarray(got_d), want_d, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_o), want_o, rtol=1e-4, atol=1e-4)
    return np.asarray(got_o), np.asarray(got_d)


@st.composite
def column_case(draw):
    bundle = draw(st.sampled_from([4, 8, 32]))
    pipes = draw(st.sampled_from([4, 32]))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    colspace = draw(st.integers(1, 60))
    kfill = rng.integers(0, min(bundle, colspace) + 1)
    kc = np.full(bundle, -1, np.int32)
    if kfill:
        kc[:kfill] = np.sort(rng.choice(colspace, kfill, replace=False))
    kv = np.where(kc >= 0, rng.standard_normal(bundle), 0).astype(np.float32)
    rc = np.full((pipes, bundle), -1, np.int32)
    for p in range(pipes):
        f = rng.integers(0, min(bundle, colspace) + 1)
        if f:
            rc[p, :f] = np.sort(rng.choice(colspace, f, replace=False))
    rv = np.where(rc >= 0, rng.standard_normal((pipes, bundle)), 0).astype(np.float32)
    av = rng.standard_normal(pipes).astype(np.float32)
    # keep the pivot positive: diag > sum(kv^2)
    ad = np.array([float(np.sum(kv * kv) + rng.uniform(0.5, 5.0))], np.float32)
    return kc, kv, rc, rv, av, ad, bundle, pipes


@settings(max_examples=25, deadline=None)
@given(column_case())
def test_matches_oracle_on_random_columns(case):
    run_both(*case)


def test_empty_rowk_is_pure_scaling():
    # k = 0: no prior columns; L(r,0) = A(r,0)/sqrt(A(0,0))
    b, p = 8, 4
    kc = np.full(b, -1, np.int32)
    kv = np.zeros(b, np.float32)
    rc = np.full((p, b), -1, np.int32)
    rv = np.zeros((p, b), np.float32)
    av = np.array([2.0, 4.0, -6.0, 0.0], np.float32)
    ad = np.array([4.0], np.float32)
    out, lkk = run_both(kc, kv, rc, rv, av, ad, b, p)
    assert lkk[0] == pytest.approx(2.0)
    np.testing.assert_allclose(out, av / 2.0, rtol=1e-6)


def test_padding_never_matches_padding():
    # row r all-padding vs row k all-padding: dot must be 0 even though
    # both store the -1 sentinel in every slot
    b, p = 4, 4
    kc = np.full(b, -1, np.int32)
    kv = np.full(b, 7.0, np.float32)  # garbage values behind padding
    rc = np.full((p, b), -1, np.int32)
    rv = np.full((p, b), 9.0, np.float32)
    av = np.ones(p, np.float32)
    ad = np.array([1.0], np.float32)
    out, lkk = run_both(kc, kv, rc, rv, av, ad, b, p)
    assert lkk[0] == pytest.approx(1.0)
    np.testing.assert_allclose(out, av, rtol=1e-6)


def test_dot_chunk_matches_update_dot():
    """cholesky_dot_chunk must compute exactly the dot the fused update
    kernel subtracts — the chunked path's correctness contract."""
    from compile.kernels.cholesky_update import cholesky_dot_chunk

    rng = np.random.default_rng(9)
    b, p = 8, 4
    colspace = 20
    kc = np.full(b, -1, np.int32)
    kc[:6] = np.sort(rng.choice(colspace, 6, replace=False))
    kv = np.where(kc >= 0, rng.standard_normal(b), 0).astype(np.float32)
    rc = np.full((p, b), -1, np.int32)
    for i in range(p):
        f = rng.integers(1, b + 1)
        rc[i, :f] = np.sort(rng.choice(colspace, f, replace=False))
    rv = np.where(rc >= 0, rng.standard_normal((p, b)), 0).astype(np.float32)

    dots = np.asarray(cholesky_dot_chunk(kc, kv, rc, rv, bundle=b, pipes=p))
    # oracle dots from the reference update with lkk == 1 and av == 0:
    # out = (0 - dot) / 1  =>  dot = -out
    av = np.zeros(p, np.float32)
    ad = np.array([float(np.sum(np.where(kc >= 0, kv, 0) ** 2) + 1.0)], np.float32)
    want_o, want_d = ref.cholesky_column_step_ref(kc, kv, rc, rv, av, ad)
    np.testing.assert_allclose(dots, -want_o * want_d[0], rtol=1e-4, atol=1e-5)


def test_chunk_pairs_sum_to_full_dot():
    """Splitting both rows into bundle chunks and summing partial dots must
    reproduce the unchunked dot (the coordinator's merge contract)."""
    from compile.kernels.cholesky_update import cholesky_dot_chunk

    rng = np.random.default_rng(10)
    b, p = 4, 2
    length = 10  # > bundle, forces 3 chunks
    cols = np.arange(length, dtype=np.int32)
    kv_full = rng.standard_normal(length).astype(np.float32)
    rv_full = rng.standard_normal((p, length)).astype(np.float32)
    expect = rv_full @ kv_full

    total = np.zeros(p, np.float64)
    nch = -(-length // b)
    for ck in range(nch):
        kc = np.full(b, -1, np.int32)
        kv = np.zeros(b, np.float32)
        sl = slice(ck * b, min((ck + 1) * b, length))
        kc[: sl.stop - sl.start] = cols[sl]
        kv[: sl.stop - sl.start] = kv_full[sl]
        for cr in range(nch):
            rc = np.full((p, b), -1, np.int32)
            rv = np.zeros((p, b), np.float32)
            sr = slice(cr * b, min((cr + 1) * b, length))
            rc[:, : sr.stop - sr.start] = cols[sr]
            rv[:, : sr.stop - sr.start] = rv_full[:, sr]
            total += np.asarray(cholesky_dot_chunk(kc, kv, rc, rv, bundle=b, pipes=p))
    np.testing.assert_allclose(total, expect, rtol=1e-4, atol=1e-5)


def test_full_factorization_through_kernel():
    """Drive a complete small LL^T column-by-column through the kernel and
    compare against numpy's Cholesky — the L1<->algorithm contract."""
    rng = np.random.default_rng(3)
    n, b, p = 10, 32, 32
    m = rng.standard_normal((n, n)).astype(np.float32)
    a = m @ m.T + n * np.eye(n, dtype=np.float32)  # SPD
    expect = np.linalg.cholesky(a.astype(np.float64))

    l = np.zeros((n, n), np.float64)
    for k in range(n):
        # row k of L, columns < k
        kc = np.full(b, -1, np.int32)
        kv = np.zeros(b, np.float32)
        kc[:k] = np.arange(k)
        kv[:k] = l[k, :k]
        # candidate rows: all r > k (dense test matrix)
        rows = np.arange(k + 1, n)
        rc = np.full((p, b), -1, np.int32)
        rv = np.zeros((p, b), np.float32)
        av = np.zeros(p, np.float32)
        for i, r in enumerate(rows):
            rc[i, :k] = np.arange(k)
            rv[i, :k] = l[r, :k]
            av[i] = a[r, k]
        ad = np.array([a[k, k]], np.float32)
        out, lkk = cholesky_column_step(kc, kv, rc, rv, av, ad)
        l[k, k] = float(np.asarray(lkk)[0])
        for i, r in enumerate(rows):
            l[r, k] = float(np.asarray(out)[i])

    np.testing.assert_allclose(l, expect, rtol=5e-3, atol=5e-3)
