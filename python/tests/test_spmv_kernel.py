"""Pallas SpMV bundle kernel vs the loop oracle (the future-work extension
kernel), plus an end-to-end y = A x check."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spmv_bundle import spmv_bundle_wave


def run_both(ts, cols, vals, x_tiles, bundle, tile_w):
    got = np.asarray(spmv_bundle_wave(ts, cols, vals, x_tiles, bundle=bundle, tile_w=tile_w))
    want = ref.spmv_bundle_wave_ref(ts, cols, vals, x_tiles, tile_w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    return got


@st.composite
def wave_case(draw):
    bundle = draw(st.sampled_from([4, 8, 32]))
    tile_w = draw(st.sampled_from([16, 64, 256]))
    n = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    ncols = draw(st.integers(1, 3)) * tile_w
    ts = (rng.integers(0, ncols // tile_w, n) * tile_w).astype(np.int32)
    cols = np.full((n, bundle), -1, np.int32)
    vals = np.zeros((n, bundle), np.float32)
    for s in range(n):
        f = rng.integers(0, bundle + 1)
        if f:
            c = np.sort(rng.choice(ncols, size=min(f, ncols), replace=False))
            cols[s, : len(c)] = c
            vals[s, : len(c)] = rng.standard_normal(len(c))
    x_tiles = rng.standard_normal((n, tile_w)).astype(np.float32)
    return ts, cols, vals, x_tiles, bundle, tile_w


@settings(max_examples=25, deadline=None)
@given(wave_case())
def test_matches_oracle(case):
    run_both(*case)


def test_all_padding_is_zero():
    b, w = 8, 16
    ts = np.zeros(2, np.int32)
    cols = np.full((2, b), -1, np.int32)
    vals = np.full((2, b), 5.0, np.float32)  # garbage behind padding
    x = np.ones((2, w), np.float32)
    got = run_both(ts, cols, vals, x, b, w)
    assert np.all(got == 0)


def test_full_spmv_through_kernel():
    """Tile a complete y = A x through the kernel and compare to dense."""
    rng = np.random.default_rng(5)
    n, b, w = 24, 8, 16
    dense = rng.standard_normal((n, n)).astype(np.float32)
    dense[rng.random((n, n)) < 0.6] = 0.0
    x = rng.standard_normal(n).astype(np.float32)
    y = np.zeros(n, np.float64)
    for i in range(n):
        nz = np.nonzero(dense[i])[0]
        for t0 in range(0, n, w):
            sel = nz[(nz >= t0) & (nz < t0 + w)]
            for lo in range(0, len(sel), b):
                chunk = sel[lo : lo + b]
                cols = np.full((1, b), -1, np.int32)
                vals = np.zeros((1, b), np.float32)
                cols[0, : len(chunk)] = chunk
                vals[0, : len(chunk)] = dense[i, chunk]
                xt = np.zeros((1, w), np.float32)
                xt[0, : min(w, n - t0)] = x[t0 : t0 + w]
                out = np.asarray(
                    spmv_bundle_wave(
                        np.array([t0], np.int32), cols, vals, xt, bundle=b, tile_w=w
                    )
                )
                y[i] += out[0]
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)
