"""Pallas SpGEMM bundle kernel vs the loop oracle — the L1 correctness
signal. Hypothesis sweeps bundle contents, padding patterns, tile offsets
and (via the shape-generic Python entry) bundle/tile sizes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spgemm_bundle import BUNDLE, TILE_W, spgemm_bundle_wave


def run_both(ts, av, bc, bv, bundle, tile_w):
    got = np.asarray(
        spgemm_bundle_wave(ts, av, bc, bv, bundle=bundle, tile_w=tile_w)
    )
    want = ref.spgemm_bundle_wave_ref(ts, av, bc, bv, tile_w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    return got


@st.composite
def wave_case(draw, max_n=4):
    """A random batched wave with realistic padding structure."""
    bundle = draw(st.sampled_from([4, 8, 32]))
    tile_w = draw(st.sampled_from([16, 64, 256]))
    n = draw(st.integers(1, max_n))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    ncols = draw(st.integers(1, 3)) * tile_w  # column space spans tiles
    ts = (rng.integers(0, max(1, ncols // tile_w), n) * tile_w).astype(np.int32)
    av = rng.standard_normal((n, bundle)).astype(np.float32)
    # per-slot B bundles with random fill levels (padding suffix)
    bc = np.full((n, bundle, bundle), -1, dtype=np.int32)
    bv = np.zeros((n, bundle, bundle), dtype=np.float32)
    for s in range(n):
        for i in range(bundle):
            fill = rng.integers(0, bundle + 1)
            if fill:
                cols = np.sort(rng.choice(ncols, size=min(fill, ncols), replace=False))
                bc[s, i, : len(cols)] = cols
                bv[s, i, : len(cols)] = rng.standard_normal(len(cols))
    return ts, av, bc, bv, bundle, tile_w


@settings(max_examples=25, deadline=None)
@given(wave_case())
def test_matches_oracle_on_random_waves(case):
    ts, av, bc, bv, bundle, tile_w = case
    run_both(ts, av, bc, bv, bundle, tile_w)


def test_all_padding_gives_zero():
    n, b, w = 2, BUNDLE, TILE_W
    ts = np.zeros(n, np.int32)
    av = np.ones((n, b), np.float32)
    bc = np.full((n, b, b), -1, np.int32)
    bv = np.zeros((n, b, b), np.float32)
    got = run_both(ts, av, bc, bv, b, w)
    assert np.all(got == 0)


def test_duplicate_columns_accumulate():
    # two B elements hitting the same output column must merge (sum)
    b, w = 4, 16
    ts = np.zeros(1, np.int32)
    av = np.array([[2.0, 0, 0, 0]], np.float32)
    bc = np.full((1, b, b), -1, np.int32)
    bv = np.zeros((1, b, b), np.float32)
    bc[0, 0, 0] = 5
    bc[0, 0, 1] = 5  # same column twice in the bundle
    bv[0, 0, 0] = 3.0
    bv[0, 0, 1] = 4.0
    got = run_both(ts, av, bc, bv, b, w)
    assert got[0, 5] == pytest.approx(2.0 * 7.0)


def test_out_of_tile_columns_dropped():
    # a column outside [tile_start, tile_start + W) contributes nothing —
    # the coordinator covers it with another tile invocation
    b, w = 4, 16
    ts = np.array([16], np.int32)
    av = np.ones((1, b), np.float32)
    bc = np.full((1, b, b), -1, np.int32)
    bv = np.zeros((1, b, b), np.float32)
    bc[0, 0, 0] = 3   # below the tile
    bc[0, 0, 1] = 40  # above the tile
    bc[0, 0, 2] = 17  # inside
    bv[0, 0, :3] = 1.0
    got = run_both(ts, av, bc, bv, b, w)
    assert got.sum() == pytest.approx(1.0)
    assert got[0, 1] == pytest.approx(1.0)  # 17 - 16


def test_matches_csr_row_product():
    # end-to-end semantic check: a full row of A times B equals the dense
    # row product when the wave covers every tile
    rng = np.random.default_rng(7)
    b, w, ncols = 8, 32, 64
    a_row = rng.standard_normal(b).astype(np.float32)
    # B rows referenced by the A row (dense for simplicity of the oracle)
    b_rows = rng.standard_normal((b, ncols)).astype(np.float32)
    # bundle-ize: B row i has its nonzero columns (here: all) chunked to b
    acc = np.zeros(ncols, np.float32)
    for t0 in range(0, ncols, w):
        ts = np.zeros(1, np.int32) + t0
        av = a_row[None, :]
        bc = np.full((1, b, b), -1, np.int32)
        bv = np.zeros((1, b, b), np.float32)
        for i in range(b):
            # take the 8 columns of this tile chunk for slot i
            cols = np.arange(ncols)
            inside = cols  # all columns; bundle holds first b of each tile
            sel = inside[(inside >= t0) & (inside < t0 + w)][:b]
            bc[0, i, : len(sel)] = sel
            bv[0, i, : len(sel)] = b_rows[i, sel]
        out = np.asarray(spgemm_bundle_wave(ts, av, bc, bv, bundle=b, tile_w=w))
        acc[t0 : t0 + w] += out[0]
    expect = a_row @ b_rows[:, :]
    # bundle capacity b < tile width w truncates columns per slot; compare
    # only the columns the bundles actually carried
    carried = np.zeros(ncols, bool)
    for t0 in range(0, ncols, w):
        carried[t0 : t0 + b] = True
    np.testing.assert_allclose(acc[carried], expect[carried], rtol=1e-4, atol=1e-4)
