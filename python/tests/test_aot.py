"""AOT pipeline tests: lowering produces loadable HLO text with the
manifest shapes, and the lowered modules carry no Python/custom-call
dependencies (the Rust runtime requirement)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile.model import aot_entry_points


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out)
    return out, manifest


def test_entry_points_lower_to_hlo_text(built):
    out, manifest = built
    for name, entry in manifest["entries"].items():
        path = os.path.join(out, entry["file"])
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        # interpret=True must have erased all pallas custom-calls
        assert "custom-call" not in text or "mosaic" not in text.lower(), name


def test_manifest_matches_files(built):
    out, manifest = built
    m2 = json.load(open(os.path.join(out, "manifest.json")))
    assert m2 == manifest
    assert set(m2["entries"]) == {"spgemm_bundle", "spmv_bundle", "cholesky_dot", "cholesky_update"}
    sp = m2["entries"]["spgemm_bundle"]
    assert sp["params"]["bundle"] == 32
    assert sp["params"]["tile_w"] == 256
    assert sp["args"][0]["dtype"] == "int32"


def test_no_mosaic_custom_calls_in_stablehlo():
    # the stronger check at the StableHLO level: interpret=True lowers the
    # pallas body to plain ops the CPU PJRT client can run
    for name, (fn, args, _meta) in aot_entry_points().items():
        ir = str(jax.jit(fn).lower(*args).compiler_ir("stablehlo"))
        assert "tpu_custom_call" not in ir, name
        assert "mosaic" not in ir.lower(), name


def test_lowering_is_deterministic(built):
    out, manifest = built
    manifest2 = aot.build(out)
    for name in manifest["entries"]:
        assert (
            manifest["entries"][name]["sha256"]
            == manifest2["entries"][name]["sha256"]
        ), f"{name} lowering not reproducible"


def test_executable_roundtrip_numerics(built):
    """Compile the lowered artifact with the local PJRT CPU client and
    compare against direct eager execution — the same check the Rust
    integration test performs through the xla crate."""
    out, _ = built
    fns = aot_entry_points()
    fn, args, _ = fns["spgemm_bundle"]
    rng = np.random.default_rng(0)
    concrete = []
    for spec in args:
        if spec.dtype == np.int32:
            concrete.append(rng.integers(0, 8, spec.shape).astype(np.int32))
        else:
            concrete.append(rng.standard_normal(spec.shape).astype(np.float32))
    eager = np.asarray(fn(*concrete))
    compiled = jax.jit(fn).lower(*args).compile()
    got = np.asarray(compiled(*concrete))
    np.testing.assert_allclose(got, eager, rtol=1e-6)
