"""L2: the FPGA compute phase as a JAX graph.

The Rust coordinator (L3) performs REAP's CPU role — RIR bundling,
scheduling, symbolic analysis — and then drives the *compiled form of this
module* through PJRT for the arithmetic the paper's FPGA performs. Each
public function here is one AOT entry point; `aot.py` lowers them to HLO
text with fixed shapes (recorded in `artifacts/manifest.json`).

The hot inner loops are the Pallas kernels in `kernels/`; this layer adds
the (thin, by design) batching and composition glue. Python never runs at
request time.
"""

import jax.numpy as jnp

from .kernels.cholesky_update import (
    BUNDLE,
    PIPES,
    cholesky_column_step,
    cholesky_dot_chunk,
)
from .kernels.spgemm_bundle import TILE_W, spgemm_bundle_wave
from .kernels.spmv_bundle import spmv_bundle_wave

# AOT batch: bundle-steps per SpGEMM artifact invocation. Small enough that
# padding waste is bounded on short waves, large enough to amortize the
# PJRT execute overhead.
SPGEMM_BATCH = 16
# SpMV steps are much lighter; a larger batch amortizes dispatch.
SPMV_BATCH = 64


def spgemm_wave(tile_start, a_vals, b_cols, b_vals):
    """Batched SpGEMM bundle-step (see `kernels/spgemm_bundle.py`).

    Shapes: i32[N], f32[N,B], i32[N,B,B], f32[N,B,B] -> f32[N, TILE_W].
    """
    return spgemm_bundle_wave(tile_start, a_vals, b_cols, b_vals)


def cholesky_column(rowk_cols, rowk_vals, rowr_cols, rowr_vals, a_vals, a_diag):
    """One Cholesky column step (see `kernels/cholesky_update.py`).

    Shapes: i32[B], f32[B], i32[P,B], f32[P,B], f32[P], f32[1]
            -> (f32[P], f32[1]).
    """
    return cholesky_column_step(rowk_cols, rowk_vals, rowr_cols, rowr_vals, a_vals, a_diag)


def spmv_wave(tile_start, cols, vals, x_tiles):
    """Batched SpMV partial products (see `kernels/spmv_bundle.py`)."""
    return spmv_bundle_wave(tile_start, cols, vals, x_tiles)


def cholesky_dot(rowk_cols, rowk_vals, rowr_cols, rowr_vals):
    """Partial matched dots for chunked rows (see `cholesky_dot_chunk`)."""
    return cholesky_dot_chunk(rowk_cols, rowk_vals, rowr_cols, rowr_vals)


def aot_entry_points():
    """The functions `aot.py` lowers, with their example arguments."""
    import jax

    n, b, w, p = SPGEMM_BATCH, BUNDLE, TILE_W, PIPES
    f32, i32 = jnp.float32, jnp.int32
    spec = jax.ShapeDtypeStruct
    return {
        "spgemm_bundle": (
            spgemm_wave,
            (
                spec((n,), i32),
                spec((n, b), f32),
                spec((n, b, b), i32),
                spec((n, b, b), f32),
            ),
            {"batch": n, "bundle": b, "tile_w": w},
        ),
        "spmv_bundle": (
            spmv_wave,
            (
                spec((SPMV_BATCH,), i32),
                spec((SPMV_BATCH, b), i32),
                spec((SPMV_BATCH, b), f32),
                spec((SPMV_BATCH, w), f32),
            ),
            {"batch": SPMV_BATCH, "bundle": b, "tile_w": w},
        ),
        "cholesky_dot": (
            cholesky_dot,
            (
                spec((b,), i32),
                spec((b,), f32),
                spec((p, b), i32),
                spec((p, b), f32),
            ),
            {"bundle": b, "pipes": p},
        ),
        "cholesky_update": (
            cholesky_column,
            (
                spec((b,), i32),
                spec((b,), f32),
                spec((p, b), i32),
                spec((p, b), f32),
                spec((p,), f32),
                spec((1,), f32),
            ),
            {"bundle": b, "pipes": p},
        ),
    }
