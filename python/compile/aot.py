"""AOT lowering: JAX/Pallas (L2/L1) -> HLO text artifacts for the Rust
runtime.

HLO *text* is the interchange format, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the published `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage:  python -m compile.aot [--out-dir ../artifacts]

Produces `<name>.hlo.txt` per entry point plus `manifest.json` describing
the fixed shapes the Rust side must pad to.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import aot_entry_points


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "entries": {}}
    for name, (fn, args, meta) in aot_entry_points().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "params": meta,
            "args": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
