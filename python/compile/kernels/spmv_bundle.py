"""L1 Pallas kernel: REAP SpMV (y = A x) over RIR bundles — the paper's
future-work extension ("many other sparse linear algebra kernels can be
accelerated with the same approach", §II), built on the same contract.

One grid step processes a batch of N row chunks against one tile of x:
for chunk s, `partial[s] = Σ_j vals[s,j] · x[cols[s,j]]` restricted to
columns inside `[tile_start, tile_start + W)`. The gather that an FPGA
would do from on-chip x RAM becomes a one-hot contraction on the MXU
(`onehot[B, W] @ x_tile[W]`), exactly mirroring the SpGEMM kernel's
CAM-to-matmul adaptation. The coordinator (L3) sums partials across
chunks/tiles of the same row — its merge role.

Padding: cols = -1, vals = 0 (contributes nothing).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BUNDLE = 32
TILE_W = 256
PAD_COL = -1


def _kernel(tile_start_ref, cols_ref, vals_ref, x_ref, out_ref, *, tile_w):
    cols = cols_ref[...]   # [B]  i32
    vals = vals_ref[...]   # [B]  f32
    x = x_ref[...]         # [W]  f32 (the tile)
    t0 = tile_start_ref[0]

    w_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_w,), 0) + t0
    onehot = (cols[:, None] == w_iota[None, :]).astype(jnp.float32)  # [B,W]
    gathered = jnp.dot(onehot, x, preferred_element_type=jnp.float32)  # [B]
    out_ref[0] = jnp.sum(vals * gathered)


@functools.partial(jax.jit, static_argnames=("bundle", "tile_w"))
def spmv_bundle_wave(tile_start, cols, vals, x_tiles, *, bundle=BUNDLE, tile_w=TILE_W):
    """Batch of N row-chunk × x-tile partial dot products.

    Args:
      tile_start: i32[N]   — first column of each step's x tile.
      cols:       i32[N,B] — row-chunk column indices, -1 padded.
      vals:       f32[N,B] — row-chunk values, 0 padded.
      x_tiles:    f32[N,W] — the x tile each step reads (the coordinator
                  slices x per step so the artifact shape stays fixed).
    Returns f32[N] partial products.
    """
    n = cols.shape[0]
    assert cols.shape == (n, bundle)
    assert vals.shape == (n, bundle)
    assert x_tiles.shape == (n, tile_w)
    assert tile_start.shape == (n,)
    return pl.pallas_call(
        functools.partial(_kernel, tile_w=tile_w),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((None, bundle), lambda i: (i, 0)),
            pl.BlockSpec((None, bundle), lambda i: (i, 0)),
            pl.BlockSpec((None, tile_w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(tile_start, cols, vals, x_tiles)
