"""Pure-numpy correctness oracles for the Pallas kernels.

Deliberately written as explicit Python loops over gathered indices — the
*semantics* of the FPGA datapath (match, multiply, merge; dot, div, sqrt) —
rather than a re-statement of the kernels' vectorized algebra, so index
errors in the kernels cannot cancel out in the oracle.
"""

import numpy as np

PAD_COL = -1


def spgemm_bundle_wave_ref(tile_start, a_vals, b_cols, b_vals, tile_w):
    """Loop oracle for `spgemm_bundle.spgemm_bundle_wave`."""
    tile_start = np.asarray(tile_start)
    a_vals = np.asarray(a_vals)
    b_cols = np.asarray(b_cols)
    b_vals = np.asarray(b_vals)
    n, bundle = a_vals.shape
    acc = np.zeros((n, tile_w), dtype=np.float64)
    for s in range(n):
        t0 = int(tile_start[s])
        for i in range(bundle):  # A elements (CAM entries)
            va = float(a_vals[s, i])
            for j in range(bundle):  # streamed B bundle slots
                c = int(b_cols[s, i, j])
                if c == PAD_COL:
                    continue
                w = c - t0
                if 0 <= w < tile_w:
                    # match -> multiply -> merge (positional accumulate)
                    acc[s, w] += va * float(b_vals[s, i, j])
    return acc.astype(np.float32)


def spmv_bundle_wave_ref(tile_start, cols, vals, x_tiles, tile_w):
    """Loop oracle for `spmv_bundle.spmv_bundle_wave`."""
    tile_start = np.asarray(tile_start)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    x_tiles = np.asarray(x_tiles)
    n, bundle = cols.shape
    out = np.zeros(n, dtype=np.float64)
    for s in range(n):
        t0 = int(tile_start[s])
        for j in range(bundle):
            c = int(cols[s, j])
            if c == PAD_COL:
                continue
            w = c - t0
            if 0 <= w < tile_w:  # gather from the on-chip x tile
                out[s] += float(vals[s, j]) * float(x_tiles[s, w])
    return out.astype(np.float32)


def cholesky_column_step_ref(rowk_cols, rowk_vals, rowr_cols, rowr_vals, a_vals, a_diag):
    """Loop oracle for `cholesky_update.cholesky_column_step`."""
    rowk_cols = np.asarray(rowk_cols)
    rowk_vals = np.asarray(rowk_vals)
    rowr_cols = np.asarray(rowr_cols)
    rowr_vals = np.asarray(rowr_vals)
    a_vals = np.asarray(a_vals)
    pipes, bundle = rowr_cols.shape

    # row k as a dict: column -> value (the CAM contents)
    cam = {
        int(c): float(v)
        for c, v in zip(rowk_cols, rowk_vals)
        if int(c) != PAD_COL
    }
    diag = float(a_diag[0]) - sum(v * v for v in cam.values())
    lkk = np.sqrt(diag)

    out = np.zeros(pipes, dtype=np.float64)
    for p in range(pipes):
        dot = 0.0
        for j in range(bundle):
            c = int(rowr_cols[p, j])
            if c == PAD_COL:
                continue
            if c in cam:  # CAM hit
                dot += float(rowr_vals[p, j]) * cam[c]
        out[p] = (float(a_vals[p]) - dot) / lkk
    return out.astype(np.float32), np.array([lkk], dtype=np.float32)
