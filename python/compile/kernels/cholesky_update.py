"""L1 Pallas kernel: the Cholesky dot-product + div/sqrt PEs over RIR
bundles (paper Fig 5(c)/(d)), rethought for the TPU.

One call computes one *column step* of the left-looking factorization:
given the broadcast of row k of L (columns < k) and a batch of P candidate
rows r (each a nonzero of column k of L), it produces

    L(r, k) = (A(r, k) - L(r, :k) . L(k, :k)) / L(k, k)
    L(k, k) = sqrt(A(k, k) - L(k, :k) . L(k, :k))

The FPGA matches row indices with per-PE CAMs; here matching is a one-hot
equality contraction (B x B per row pair) feeding the MXU, and the div/sqrt
PE is a VPU rsqrt/div over the P-vector — every pipeline computing the
diagonal redundantly in the paper collapses into one shared rsqrt here
(the TPU has no independence constraint to buy back).

Padding: column slots are -1 and values 0; -1 == -1 equalities are masked
so padding never matches padding.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bundle size (CAM entries) and pipeline batch, both 32 in the paper.
BUNDLE = 32
PIPES = 32
PAD_COL = -1


def _kernel(
    rowk_cols_ref,
    rowk_vals_ref,
    rowr_cols_ref,
    rowr_vals_ref,
    a_vals_ref,
    a_diag_ref,
    out_ref,
    lkk_ref,
):
    kc = rowk_cols_ref[...]  # [B]   i32, -1 padded
    kv = rowk_vals_ref[...]  # [B]   f32
    rc = rowr_cols_ref[...]  # [P,B] i32, -1 padded
    rv = rowr_vals_ref[...]  # [P,B] f32
    av = a_vals_ref[...]     # [P]   f32  (A(r,k); 0 where absent)
    ad = a_diag_ref[0]       # scalar    (A(k,k))

    k_valid = kc >= 0
    kvm = jnp.where(k_valid, kv, 0.0)

    # CAM match as one-hot equality, padding masked on both sides
    eq = (rc[:, :, None] == kc[None, None, :]) & (rc[:, :, None] >= 0) & k_valid[None, None, :]
    # matched[r, j] = value of row k at the column that slot j of row r hits
    matched = jnp.einsum(
        "pjm,m->pj", eq.astype(jnp.float32), kvm, preferred_element_type=jnp.float32
    )
    dots = jnp.sum(rv * matched, axis=1)  # [P]

    diag = ad - jnp.sum(kvm * kvm)
    lkk = jnp.sqrt(diag)
    out_ref[...] = (av - dots) / lkk
    lkk_ref[0] = lkk


def _dot_kernel(rowk_cols_ref, rowk_vals_ref, rowr_cols_ref, rowr_vals_ref, dots_ref):
    kc = rowk_cols_ref[...]
    kv = rowk_vals_ref[...]
    rc = rowr_cols_ref[...]
    rv = rowr_vals_ref[...]
    k_valid = kc >= 0
    kvm = jnp.where(k_valid, kv, 0.0)
    eq = (rc[:, :, None] == kc[None, None, :]) & (rc[:, :, None] >= 0) & k_valid[None, None, :]
    matched = jnp.einsum(
        "pjm,m->pj", eq.astype(jnp.float32), kvm, preferred_element_type=jnp.float32
    )
    dots_ref[...] = jnp.sum(rv * matched, axis=1)


@functools.partial(jax.jit, static_argnames=("bundle", "pipes"))
def cholesky_dot_chunk(rowk_cols, rowk_vals, rowr_cols, rowr_vals, *, bundle=BUNDLE, pipes=PIPES):
    """Partial matched dot products for one (row-k chunk, row-r chunk) pair.

    Rows of L longer than one bundle are processed as chunk pairs; the
    coordinator sums the partials (the merge role it owns) and finalizes
    via `cholesky_column_step` with an empty row-k broadcast. Returns
    `dots[P]`.
    """
    assert rowk_cols.shape == (bundle,)
    assert rowr_cols.shape == (pipes, bundle)
    return pl.pallas_call(
        _dot_kernel,
        out_shape=jax.ShapeDtypeStruct((pipes,), jnp.float32),
        interpret=True,
    )(rowk_cols, rowk_vals, rowr_cols, rowr_vals)


@functools.partial(jax.jit, static_argnames=("bundle", "pipes"))
def cholesky_column_step(
    rowk_cols, rowk_vals, rowr_cols, rowr_vals, a_vals, a_diag, *, bundle=BUNDLE, pipes=PIPES
):
    """One batched column step. Returns `(l_rk[P], l_kk[1])`.

    Args:
      rowk_cols: i32[B]   — columns of row k of L (< k), -1 padded.
      rowk_vals: f32[B]   — matching values.
      rowr_cols: i32[P,B] — columns of each candidate row r (< k), -1 pad.
      rowr_vals: f32[P,B] — matching values.
      a_vals:    f32[P]   — A(r, k) per candidate row (0 where absent).
      a_diag:    f32[1]   — A(k, k).
    """
    assert rowk_cols.shape == (bundle,)
    assert rowr_cols.shape == (pipes, bundle)
    assert a_vals.shape == (pipes,)
    assert a_diag.shape == (1,)
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((pipes,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(rowk_cols, rowk_vals, rowr_cols, rowr_vals, a_vals, a_diag)
