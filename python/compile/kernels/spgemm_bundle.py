"""L1 Pallas kernel: the SpGEMM match+multiply+merge datapath over RIR
bundles, rethought for the TPU (DESIGN.md §Hardware-Adaptation).

The FPGA design matches B elements against a 32-entry CAM, multiplies the
matches, insertion-sorts the partial products and merges equal column
indices. On a TPU none of those primitives exist; the same *insight* —
"the CPU has already regularized the data into fixed-size bundles, so the
datapath runs dense" — maps to:

* CAM match        -> one-hot equality against a column-tile iota,
* multiply         -> elementwise partial-product tile,
* sort+merge       -> positional accumulation: `pp_flat @ onehot_flat`,
                      a single [B*B, W] contraction on the MXU.

Shapes (one grid step): `a_vals[B]` is a row-of-A chunk (the CAM contents),
`b_cols/b_vals[B, B]` hold, for each A element, the bundle of the B row it
references (padded with col = -1, val = 0), and the output `acc[W]` is the
dense accumulator for the column tile starting at `tile_start`.

VMEM per program (B=32, W=256): one-hot f32 [1024, W] = 1 MiB plus
operands ≈ 1.05 MiB — comfortably under a TPU core's ~16 MiB VMEM with
double-buffering room. The contraction is [1,1024]x[1024,256] f32 on the
MXU.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's design point: RIR bundle size = CAM size = 32.
BUNDLE = 32
# Column-tile width of the positional accumulator (power of two, one MXU
# pass; 256 keeps the one-hot operand at 1 MiB of VMEM).
TILE_W = 256
# Padding sentinel for column indices (never matches a real tile column).
PAD_COL = -1


def _kernel(tile_start_ref, a_vals_ref, b_cols_ref, b_vals_ref, acc_ref, *, tile_w):
    a_vals = a_vals_ref[...]          # [B]   f32
    b_cols = b_cols_ref[...]          # [B,B] i32
    b_vals = b_vals_ref[...]          # [B,B] f32
    t0 = tile_start_ref[0]            # scalar i32

    # match+multiply: partial products (padding contributes 0)
    pp = a_vals[:, None] * b_vals     # [B,B]

    # sort+merge as positional accumulation over the column tile
    w_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_w,), 0) + t0
    onehot = (b_cols[:, :, None] == w_iota[None, None, :]).astype(jnp.float32)
    b = pp.shape[0] * pp.shape[1]
    acc = jnp.dot(
        pp.reshape(1, b),
        onehot.reshape(b, tile_w),
        preferred_element_type=jnp.float32,
    )                                  # [1, W]
    acc_ref[...] = acc[0]


@functools.partial(jax.jit, static_argnames=("bundle", "tile_w"))
def spgemm_bundle_wave(tile_start, a_vals, b_cols, b_vals, *, bundle=BUNDLE, tile_w=TILE_W):
    """Process a batch of N bundle-steps: returns `acc[N, tile_w]`.

    Args:
      tile_start: i32[N]   — first output column of each step's tile.
      a_vals:     f32[N,B] — A-chunk values (CAM payloads), 0-padded.
      b_cols:     i32[N,B,B] — per-A-element B-row column bundles, -1 pad.
      b_vals:     f32[N,B,B] — matching values, 0-padded.
    """
    n = a_vals.shape[0]
    assert a_vals.shape == (n, bundle), a_vals.shape
    assert b_cols.shape == (n, bundle, bundle), b_cols.shape
    assert b_vals.shape == b_cols.shape
    assert tile_start.shape == (n,)
    return pl.pallas_call(
        functools.partial(_kernel, tile_w=tile_w),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            # `None` squeezes the grid-indexed leading axis away
            pl.BlockSpec((None, bundle), lambda i: (i, 0)),
            pl.BlockSpec((None, bundle, bundle), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, bundle, bundle), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, tile_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, tile_w), jnp.float32),
        interpret=True,
    )(tile_start, a_vals, b_cols, b_vals)
